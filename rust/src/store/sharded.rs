//! Shard router: N independent [`KvStore`]s behind per-shard
//! reader/writer locks (memcached's item-lock striping, coarsened to
//! whole shards).
//!
//! ## Lock discipline
//!
//! Mutating commands take the shard's write lock. `get`s first probe
//! under the shard's **read** lock via [`KvStore::peek`] — items
//! accessed within [`TOUCH_INTERVAL`] are served concurrently with
//! zero store mutation (hit/miss counters live in per-shard atomics).
//! Only expired items and items due an LRU bump fall back to the
//! write-locked [`KvStore::get_with`] path, so a get-heavy workload on
//! one shard no longer serializes.
//!
//! ## Optimistic (lock-free) reads
//!
//! [`get_optimistic`] and [`meta_get_optimistic`] go one step further:
//! they take **no lock at all**. The probe walks the published hash
//! geometry ([`TablePub`]) and arena slots ([`ArenaPub`]) with volatile
//! copies, validated against the shard's seqlock stripes
//! ([`SeqStripes`]): snapshot the stripe of the key's hash, copy, and
//! accept the result only if the stripe never moved. Every writer wraps
//! its reader-visible mutations in a stripe window, and the hash
//! table's ≥ 64-bucket floor makes "stripe of the hash" = "stripe of
//! the bucket", so one stripe covers the whole chain the reader walks.
//! A failed validation retries a few times, then falls back to the
//! locked paths ([`ReadAttempt::Fallback`]). Read-side effects (LRU
//! bump, access-time refresh, fetched bit) are not applied inline:
//! stale hits enqueue a [`BumpEvent`] on the shard's bounded MPSC ring,
//! drained by the maintainer under one short write-lock lease
//! ([`ShardedStore::drain_deferred`]); a full ring drops the bump
//! (counted, never blocking).
//!
//! [`TOUCH_INTERVAL`]: crate::store::store::TOUCH_INTERVAL
//! [`get_optimistic`]: ShardedStore::get_optimistic
//! [`meta_get_optimistic`]: ShardedStore::meta_get_optimistic
//! [`TablePub`]: super::optimistic::TablePub
//! [`ArenaPub`]: super::optimistic::ArenaPub
//! [`SeqStripes`]: super::optimistic::SeqStripes
//! [`BumpEvent`]: super::optimistic::BumpEvent
//!
//! ## Routing
//!
//! Keys route by a multiplicative fold of the full 64-bit key hash
//! (splitmix64 finalizer). The per-shard hash tables index buckets with
//! the *raw* low bits of the same hash, so the fold also decorrelates
//! shard choice from bucket choice. (The previous `hash >> 56` routing
//! used only the top byte — at most 256 distinct routes, and badly
//! skewed the moment shard counts stopped dividing 256.)
//!
//! ## Poisoning policy
//!
//! Every guard acquisition recovers from a poisoned lock via
//! [`PoisonError::into_inner`] (see [`Shard::read`]/[`Shard::write`])
//! instead of unwrapping. This is deliberate, not a shrug: a panic
//! inside a `KvStore` call can only come from a bug or an injected
//! failpoint, and every mutating entry point either completes its
//! update transactionally or fails before mutating (migration
//! failpoints sit at function entry for exactly this reason). The
//! protected state is therefore re-validated rather than presumed
//! corrupt — `KvStore::check_integrity` is the arbiter, and the chaos
//! suite runs it after every injected panic. The alternative (bare
//! `.unwrap()`) turns one panicked writer into a `PoisonError` cascade
//! that takes down every connection touching the shard — strictly
//! worse for a cache that holds 15 other shards of good data. The one
//! place we still abort-with-message is `begin_reconfigure`'s
//! generation flip: failing mid-flip would leave shards on divergent
//! geometries, so an error there is unrecoverable by design (and
//! unreachable: the policy is validated before any shard flips).

use super::arena::{ItemMeta, NIL};
use super::item::hash_key;
use super::migrate::{MigrationGauges, DEFAULT_MIGRATE_BATCH};
use super::optimistic::{
    ArenaPub, BumpEvent, BumpRing, ReadLanes, SeqStripes, TablePub, BUMP_RING_CAP,
};
use super::store::{
    ArithOpts, ArithOutcome, CasResult, Clock, DeleteOutcome, ItemDebug, KvStore, MetaGetOpts,
    MetaHit, MetaSetOpts, MigrationReport, PeekOutcome, SetOutcome, SizeObserver, StoreError,
    StoreStats, Value, ValueRef, TOUCH_INTERVAL,
};
use crate::config::Settings;
use crate::slab::class::ClassStats;
use crate::slab::policy::ChunkSizePolicy;
use crate::slab::{SlabError, SlabRegion, SlabStats};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Keys routed on the stack per multiget batch; longer batches spill
/// to one transient allocation.
const INLINE_BATCH: usize = 64;

/// Values at or above this size are never served by the optimistic
/// path: the connection layer scatter-writes large values straight to
/// the socket (`DIRECT_VALUE_MIN`), which cannot be undone if the
/// post-encode seqlock validation fails. Smaller values are encoded
/// into the response buffer, which a failed attempt simply truncates.
pub const OPTIMISTIC_VALUE_MAX: usize = 4096;

/// Optimistic probe attempts before falling back to the locked path.
const OPTIMISTIC_RETRIES: usize = 4;

/// Chain-hop bound per probe. A genuine chain is far shorter (the
/// table expands at load factor 1.5); exceeding the bound means the
/// walk is chasing torn links and must retry.
const MAX_PROBE_HOPS: usize = 256;

/// Outcome of an optimistic (lock-free) read
/// ([`ShardedStore::get_optimistic`] /
/// [`ShardedStore::meta_get_optimistic`]).
pub enum ReadAttempt<R> {
    /// Served without any lock; the visitor's output was validated.
    Hit(R),
    /// Definitively absent under a validated probe.
    Miss,
    /// The lock-free path cannot serve this request — torn-read retries
    /// exhausted, expired item, oversized value, or flags that require
    /// the write path. The caller retries on the locked paths, whose
    /// semantics (and stats accounting) then apply.
    Fallback,
}

/// One optimistic probe attempt's outcome (internal; the public
/// surface folds `Torn` retries and `Unservable` into
/// [`ReadAttempt::Fallback`]).
enum ProbeStep<R> {
    /// Validated hit; the deferred bump (if the item is recency-stale)
    /// rides along for the caller to enqueue.
    Hit(R, Option<BumpEvent>),
    Miss,
    /// Seqlock validation failed somewhere — retry.
    Torn,
    /// The item exists but only the locked path may serve it (expired:
    /// lazy reclaim mutates; oversized: scatter-write hazard).
    Unservable,
}

/// One shard: the store behind an RwLock, plus lock-free counters for
/// gets served on the read path (where `&mut StoreStats` is
/// unavailable). [`ShardedStore::stats`] merges both sources.
struct Shard {
    store: RwLock<KvStore>,
    read_gets: AtomicU64,
    read_hits: AtomicU64,
    read_misses: AtomicU64,
    /// Seqlock stripes shared with the shard's writers (the store and
    /// its hash table bump these around every reader-visible mutation).
    seq: Arc<SeqStripes>,
    /// Published arena base/len for lock-free slot reads.
    apub: Arc<ArenaPub>,
    /// Published hash-table geometry for lock-free bucket walks.
    tpub: Arc<TablePub>,
    /// The store's clock, cloned so expiry checks need no lock.
    clock: Clock,
    /// Deferred read-side effects (LRU bumps, fetched bits) queued by
    /// optimistic hits, drained by the maintainer.
    ring: BumpRing,
    /// Striped counters for the optimistic path (gets/hits/misses plus
    /// seqlock retries/fallbacks and bump queue/drop counts).
    lanes: ReadLanes,
}

impl Shard {
    fn new(store: KvStore) -> Self {
        let (seq, apub, tpub, clock) = store.read_handles();
        Shard {
            store: RwLock::new(store),
            read_gets: AtomicU64::new(0),
            read_hits: AtomicU64::new(0),
            read_misses: AtomicU64::new(0),
            seq,
            apub,
            tpub,
            clock,
            ring: BumpRing::new(BUMP_RING_CAP),
            lanes: ReadLanes::new(),
        }
    }

    /// Read guard, recovering from poisoning (see module docs).
    fn read(&self) -> RwLockReadGuard<'_, KvStore> {
        self.store.read().unwrap_or_else(PoisonError::into_inner)
    }

    /// Write guard, recovering from poisoning (see module docs).
    fn write(&self) -> RwLockWriteGuard<'_, KvStore> {
        self.store.write().unwrap_or_else(PoisonError::into_inner)
    }

    /// One lock-free probe attempt for `key` under the seqlock protocol.
    ///
    /// Ordering is load-bearing: the stripe snapshot `s1` is taken
    /// **first**, then the table view and arena base/len (all Acquire).
    /// Any writer whose mutation precedes `s1` finished with a Release
    /// stripe bump, so the Acquire load of `s1` makes that mutation —
    /// and, via the write lock's ordering, every earlier republish of
    /// the arena or table — visible to this probe. Snapshots taken
    /// *before* `s1` could be stale yet still pass validation.
    ///
    /// Chunk bytes are dereferenced only after (a) the stripe validated
    /// post-meta-copy and (b) the copied record is live with our hash,
    /// key length, and a non-zero `chunk_addr`. A torn `chunk_addr`
    /// cannot clear both gates: a writer mutating an item in our bucket
    /// holds our stripe (caught by (a)), and a writer recycling the
    /// slot for another bucket never stores our hash value into it
    /// (caught by (b)). Walks through garbage links are bounded by the
    /// arena-length check and [`MAX_PROBE_HOPS`]; byte derefs are
    /// bounded by the key-length limit and [`OPTIMISTIC_VALUE_MAX`].
    ///
    /// `enc` encodes the hit into caller-owned storage (`ctx`); if the
    /// **post-encode** validation fails, `reset` must undo the encode
    /// (truncate the output buffer) before the retry.
    fn probe<C, R>(
        &self,
        key: &[u8],
        hash: u64,
        ctx: &mut C,
        reset: &mut impl FnMut(&mut C),
        enc: &mut impl FnMut(&mut C, &ItemMeta, u32, ValueRef<'_>) -> R,
    ) -> ProbeStep<R> {
        let stripe = SeqStripes::stripe_of(hash);
        let s1 = self.seq.begin_read(stripe);
        if s1 & 1 == 1 {
            return ProbeStep::Torn; // writer in flight on our stripe
        }
        let Some(view) = self.tpub.snapshot() else {
            return ProbeStep::Torn;
        };
        let abase = self.apub.base.load(Ordering::Acquire) as *const ItemMeta;
        let alen = self.apub.len.load(Ordering::Acquire);
        let now = self.clock.now();
        // The view does not expose migration progress, so walk the
        // bucket in *both* arrays: during an expansion an item is
        // linked in exactly one of them at any validated instant.
        let heads = [
            (view.prim_base, view.prim_mask),
            (view.old_base, view.old_mask),
        ];
        for &(base, mask) in &heads {
            if base == 0 {
                continue; // no old array
            }
            let mut id = unsafe {
                std::ptr::read_volatile((base as *const u32).add((hash & mask) as usize))
            };
            let mut hops = 0usize;
            while id != NIL {
                hops += 1;
                if hops > MAX_PROBE_HOPS || (id as usize) >= alen {
                    return ProbeStep::Torn; // torn link or stale id
                }
                let m = unsafe { std::ptr::read_volatile(abase.add(id as usize)) };
                if !self.seq.validate(stripe, s1) {
                    return ProbeStep::Torn;
                }
                if !m.live
                    || m.hash != hash
                    || m.klen as usize != key.len()
                    || m.chunk_addr == 0
                {
                    id = m.hnext;
                    continue;
                }
                if crate::util::failpoint::fired("store.seqlock.stall") {
                    std::thread::sleep(std::time::Duration::from_millis(1));
                }
                // Revalidate before the first chunk deref: the copied
                // record is now known consistent, so `chunk_addr` is a
                // real chunk base that limbo keeps mapped.
                if !self.seq.validate(stripe, s1) {
                    return ProbeStep::Torn;
                }
                let kbytes = unsafe {
                    std::slice::from_raw_parts(m.chunk_addr as *const u8, m.klen as usize)
                };
                if kbytes != key {
                    id = m.hnext;
                    continue;
                }
                if m.exptime != 0 && m.exptime <= now {
                    return ProbeStep::Unservable; // lazy reclaim mutates
                }
                if m.vlen as usize >= OPTIMISTIC_VALUE_MAX {
                    return ProbeStep::Unservable; // scatter-write hazard
                }
                let vbytes = unsafe {
                    std::slice::from_raw_parts(
                        (m.chunk_addr + m.klen as usize) as *const u8,
                        m.vlen as usize,
                    )
                };
                let r = enc(
                    ctx,
                    &m,
                    now,
                    ValueRef {
                        data: vbytes,
                        flags: m.flags,
                        cas: m.cas,
                    },
                );
                if !self.seq.validate(stripe, s1) {
                    reset(ctx); // the encode may have copied torn bytes
                    return ProbeStep::Torn;
                }
                let bump = if now.saturating_sub(m.time) >= TOUCH_INTERVAL {
                    Some(BumpEvent {
                        id,
                        gen: m.gen,
                        cas: m.cas,
                        now,
                    })
                } else {
                    None
                };
                return ProbeStep::Hit(r, bump);
            }
        }
        // A miss is only a miss if both walks ran against a stable stripe.
        if self.seq.validate(stripe, s1) {
            ProbeStep::Miss
        } else {
            ProbeStep::Torn
        }
    }
}

/// Thread-safe sharded cache — the object the TCP server serves.
pub struct ShardedStore {
    shards: Vec<Shard>,
    page_size: usize,
    /// Items a migration step may move per shard while holding the
    /// shard write lock (the `migrate_batch` setting).
    migrate_batch: AtomicUsize,
    /// Tenant registry: always present, inactive (and free) until a
    /// tenant is defined. Also attached to every shard as its
    /// `TenantSink`, so per-tenant byte gauges track every store/free.
    tenants: Arc<crate::tenant::TenantRegistry>,
    /// The mmap-backed page region behind `--memory-file` (`None` when
    /// persistence is off). Shared by every shard's allocator; kept
    /// here so shutdown can msync it and locate the manifest path.
    region: Option<SlabRegion>,
    /// Boot-scoped warm-restart gauges (`stats`: `restart_*` rows).
    restart: RestartGauges,
}

/// How the current boot obtained its contents. Boot-scoped: set once
/// during startup and deliberately **not** cleared by `stats reset`
/// (an operator diagnosing a cold start must still see why after a
/// monitoring agent resets the counters).
struct RestartGauges {
    /// 0 = persistence disabled, 1 = warm, 2 = cold.
    state: AtomicU8,
    items_recovered: AtomicU64,
    items_discarded: AtomicU64,
    duration_ms: AtomicU64,
    /// Why a cold start degraded (empty for warm/disabled).
    reason: Mutex<String>,
}

impl Default for RestartGauges {
    fn default() -> Self {
        RestartGauges {
            state: AtomicU8::new(0),
            items_recovered: AtomicU64::new(0),
            items_discarded: AtomicU64::new(0),
            duration_ms: AtomicU64::new(0),
            reason: Mutex::new(String::new()),
        }
    }
}

/// Snapshot of the warm-restart gauges for the stats renderer.
#[derive(Clone, Debug, Default)]
pub struct RestartSnapshot {
    /// `"disabled"`, `"warm"`, or `"cold"`.
    pub state: &'static str,
    /// Degradation reason (empty unless `state == "cold"`).
    pub reason: String,
    pub items_recovered: u64,
    pub items_discarded: u64,
    pub duration_ms: u64,
}

/// splitmix64 finalizer: a multiplicative fold in which every input
/// bit influences every output bit.
#[inline]
fn mix(mut h: u64) -> u64 {
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^= h >> 31;
    h
}

impl ShardedStore {
    /// Build from [`Settings`] (shard count, memory split, policy,
    /// migration step budget).
    pub fn new(settings: &Settings) -> Result<Self, SlabError> {
        let store = Self::with(
            settings.policy.clone(),
            settings.page_size,
            settings.mem_limit,
            settings.use_cas,
            settings.shards,
            Clock::System,
        )?;
        store.set_migrate_batch(settings.migrate_batch);
        store
            .tenants
            .set_tuning(settings.tenant_divergence, settings.tenant_reclaim_batch);
        for spec in &settings.tenants {
            store
                .tenants
                .define(&spec.name, &spec.prefix, Some(spec.quota_pages))
                .expect("tenant specs are validated by Settings::validate");
        }
        Ok(store)
    }

    /// Fully explicit constructor (tests, benches).
    pub fn with(
        policy: ChunkSizePolicy,
        page_size: usize,
        mem_limit: usize,
        use_cas: bool,
        shards: usize,
        clock: Clock,
    ) -> Result<Self, SlabError> {
        Self::with_region(policy, page_size, mem_limit, use_cas, shards, clock, None)
    }

    /// [`ShardedStore::with`], with every shard's allocator drawing its
    /// pages from a shared mmap-backed [`SlabRegion`] instead of the
    /// heap (the `--memory-file` warm-restart substrate). The region's
    /// free-extent list is shared, so its capacity must cover the sum
    /// of per-shard page budgets (plus migration slack) — the restart
    /// module sizes it.
    pub(crate) fn with_region(
        policy: ChunkSizePolicy,
        page_size: usize,
        mem_limit: usize,
        use_cas: bool,
        shards: usize,
        clock: Clock,
        region: Option<SlabRegion>,
    ) -> Result<Self, SlabError> {
        assert!(shards > 0);
        let per_shard = (mem_limit / shards).max(page_size);
        let stores: Result<Vec<_>, SlabError> = (0..shards)
            .map(|_| {
                KvStore::with_region(
                    policy.clone(),
                    page_size,
                    per_shard,
                    use_cas,
                    clock.clone(),
                    region.clone(),
                )
                .map(Shard::new)
            })
            .collect();
        let tenants = Arc::new(crate::tenant::TenantRegistry::new(page_size));
        let store = ShardedStore {
            shards: stores?,
            page_size,
            migrate_batch: AtomicUsize::new(DEFAULT_MIGRATE_BATCH),
            tenants,
            region,
            restart: RestartGauges::default(),
        };
        let sink: Arc<dyn crate::store::store::TenantSink> = store.tenants.clone();
        for s in &store.shards {
            s.write().set_tenant_sink(sink.clone());
        }
        Ok(store)
    }

    /// The store's tenant registry (attribution, per-tenant stats,
    /// arbitration). Inactive — and effectively free — until a tenant
    /// is defined via config or the `tenants` admin command.
    pub fn tenants(&self) -> &Arc<crate::tenant::TenantRegistry> {
        &self.tenants
    }

    /// Arbitration enforcement across shards: evict up to
    /// `max_per_shard` cold items of the masked tenants from each
    /// shard, one short write lease at a time (see
    /// [`KvStore::reclaim_tenants`]). Returns total items reclaimed.
    pub fn reclaim_tenants(&self, mask: u64, max_per_shard: usize) -> usize {
        if mask == 0 {
            return 0;
        }
        self.shards
            .iter()
            .map(|s| s.write().reclaim_tenants(mask, max_per_shard))
            .sum()
    }

    /// Per-step item budget for incremental migration.
    pub fn migrate_batch(&self) -> usize {
        self.migrate_batch.load(Ordering::Relaxed)
    }

    /// Tune the per-step item budget (≥ 1).
    pub fn set_migrate_batch(&self, n: usize) {
        self.migrate_batch.store(n.max(1), Ordering::Relaxed);
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard a key routes to (stable for a given shard count).
    #[inline]
    pub fn shard_index(&self, key: &[u8]) -> usize {
        (mix(hash_key(key)) % self.shards.len() as u64) as usize
    }

    #[inline]
    fn write_shard(&self, key: &[u8]) -> RwLockWriteGuard<'_, KvStore> {
        self.shards[self.shard_index(key)].write()
    }

    /// Attach a size observer to every shard.
    pub fn set_observer(&self, obs: Arc<dyn SizeObserver>) {
        for s in &self.shards {
            s.write().set_observer(obs.clone());
        }
    }

    // ------------------------------------------------------------- ops

    pub fn set(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> Result<(), StoreError> {
        self.write_shard(key).set(key, value, flags, exptime)
    }

    pub fn add(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> Result<bool, StoreError> {
        self.write_shard(key).add(key, value, flags, exptime)
    }

    pub fn replace(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> Result<bool, StoreError> {
        self.write_shard(key).replace(key, value, flags, exptime)
    }

    pub fn cas(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, cas: u64) -> Result<CasResult, StoreError> {
        self.write_shard(key).cas(key, value, flags, exptime, cas)
    }

    pub fn concat(&self, key: &[u8], data: &[u8], append: bool) -> Result<bool, StoreError> {
        self.write_shard(key).concat(key, data, append)
    }

    /// `get` (allocating wrapper over [`get_with`]).
    ///
    /// [`get_with`]: ShardedStore::get_with
    pub fn get(&self, key: &[u8]) -> Option<Value> {
        self.get_with(key, |v: ValueRef<'_>| Value {
            value: v.data.to_vec(),
            flags: v.flags,
            cas: v.cas,
        })
    }

    /// Zero-copy `get`: run `f` over the value bytes while they still
    /// sit in the slab chunk, under the shard lock. Recently-accessed
    /// items are served under the shard's *read* lock; expired or
    /// recency-stale items retry once under the write lock.
    pub fn get_with<R, F: FnMut(ValueRef<'_>) -> R>(&self, key: &[u8], mut f: F) -> Option<R> {
        let shard = &self.shards[self.shard_index(key)];
        {
            let s = shard.read();
            match s.peek(key, &mut f) {
                PeekOutcome::Hit(r) => {
                    shard.read_gets.fetch_add(1, Ordering::Relaxed);
                    shard.read_hits.fetch_add(1, Ordering::Relaxed);
                    return Some(r);
                }
                PeekOutcome::Miss => {
                    shard.read_gets.fetch_add(1, Ordering::Relaxed);
                    shard.read_misses.fetch_add(1, Ordering::Relaxed);
                    return None;
                }
                PeekOutcome::NeedsWrite => {}
            }
        }
        shard.write().get_with(key, f)
    }

    /// Lock-free `get`: probe the published table/arena under the
    /// shard's seqlock stripes without touching either shard lock.
    ///
    /// `enc` encodes a validated hit into `ctx` (the caller's response
    /// buffer); a hit is only returned after the stripe revalidated
    /// *post-encode*, so the encoded bytes are never torn. When that
    /// final validation fails, `reset` undoes the encode (truncate
    /// `ctx` back to its pre-call mark) and the probe retries — which
    /// is why the closures are `FnMut`, not `FnOnce`.
    ///
    /// Returns [`ReadAttempt::Fallback`] when the optimistic path
    /// cannot serve (retries exhausted, expired item, value ≥
    /// [`OPTIMISTIC_VALUE_MAX`]); the caller then uses [`get_with`],
    /// which does its own stats accounting (a fallback increments only
    /// `seqlock_fallbacks`, never double-counts the get).
    ///
    /// Recency-stale hits are still served lock-free: the LRU bump and
    /// fetched bit are queued on the shard's [`BumpRing`] for the
    /// maintainer ([`drain_deferred`]) instead of being applied inline.
    ///
    /// [`get_with`]: ShardedStore::get_with
    /// [`BumpRing`]: super::optimistic::BumpRing
    /// [`drain_deferred`]: ShardedStore::drain_deferred
    pub fn get_optimistic<C, R>(
        &self,
        key: &[u8],
        ctx: &mut C,
        mut reset: impl FnMut(&mut C),
        mut f: impl FnMut(&mut C, ValueRef<'_>) -> R,
    ) -> ReadAttempt<R> {
        let hash = hash_key(key);
        let shard = &self.shards[(mix(hash) % self.shards.len() as u64) as usize];
        let lane = shard.lanes.lane();
        for _ in 0..OPTIMISTIC_RETRIES {
            let mut enc = |c: &mut C, _m: &ItemMeta, _now: u32, v: ValueRef<'_>| f(c, v);
            match shard.probe(key, hash, ctx, &mut reset, &mut enc) {
                ProbeStep::Hit(r, bump) => {
                    lane.gets.fetch_add(1, Ordering::Relaxed);
                    lane.hits.fetch_add(1, Ordering::Relaxed);
                    if let Some(ev) = bump {
                        if shard.ring.push(ev) {
                            lane.bump_queued.fetch_add(1, Ordering::Relaxed);
                        } else {
                            lane.bump_dropped.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    return ReadAttempt::Hit(r);
                }
                ProbeStep::Miss => {
                    lane.gets.fetch_add(1, Ordering::Relaxed);
                    lane.misses.fetch_add(1, Ordering::Relaxed);
                    return ReadAttempt::Miss;
                }
                ProbeStep::Torn => {
                    lane.retries.fetch_add(1, Ordering::Relaxed);
                    std::hint::spin_loop();
                }
                ProbeStep::Unservable => {
                    lane.fallbacks.fetch_add(1, Ordering::Relaxed);
                    return ReadAttempt::Fallback;
                }
            }
        }
        lane.fallbacks.fetch_add(1, Ordering::Relaxed);
        ReadAttempt::Fallback
    }

    /// Lock-free meta retrieval: like [`get_optimistic`], with the
    /// per-hit metadata echoes built from the validated record copy.
    ///
    /// Requests the optimistic path cannot answer exactly go straight
    /// to [`ReadAttempt::Fallback`] **uncounted** (they are protocol
    /// shape, not seqlock failures): touch-on-read (`T` mutates), a
    /// bumping hit-before echo (`h` without `u` must read+set the
    /// fetched bit atomically), and base64 keys (the vivify path owns
    /// their validation). A vivifiable miss likewise falls back
    /// uncounted — creation needs the write lock. With `u` (no-bump)
    /// the hit never queues a deferred bump.
    ///
    /// [`get_optimistic`]: ShardedStore::get_optimistic
    pub fn meta_get_optimistic<C, R>(
        &self,
        key: &[u8],
        opts: &MetaGetOpts,
        ctx: &mut C,
        mut reset: impl FnMut(&mut C),
        mut f: impl FnMut(&mut C, ValueRef<'_>, MetaHit) -> R,
    ) -> ReadAttempt<R> {
        if opts.touch.is_some()
            || (opts.wants_hit_before && !opts.no_bump)
            || opts.binary_key
            || opts.recache.is_some()
        {
            // recache (`R`) joins touch here: deciding the W/Z win
            // mutates the item's win token, a write-path job
            return ReadAttempt::Fallback;
        }
        let hash = hash_key(key);
        let shard = &self.shards[(mix(hash) % self.shards.len() as u64) as usize];
        let lane = shard.lanes.lane();
        for _ in 0..OPTIMISTIC_RETRIES {
            let mut saw_stale = false;
            let mut enc = |c: &mut C, m: &ItemMeta, now: u32, v: ValueRef<'_>| {
                saw_stale = m.stale;
                let hit = MetaHit {
                    ttl: if m.exptime == 0 {
                        -1
                    } else {
                        m.exptime as i64 - now as i64
                    },
                    won: false,
                    la: now.saturating_sub(m.time),
                    fetched: m.fetched,
                    stale: false,
                    lost: false,
                };
                f(c, v, hit)
            };
            match shard.probe(key, hash, ctx, &mut reset, &mut enc) {
                ProbeStep::Hit(r, bump) => {
                    if saw_stale {
                        // a stale hit must run the write-path win race;
                        // undo the staged encode and fall back (counted:
                        // the probe did the work and threw it away)
                        reset(ctx);
                        lane.fallbacks.fetch_add(1, Ordering::Relaxed);
                        return ReadAttempt::Fallback;
                    }
                    lane.gets.fetch_add(1, Ordering::Relaxed);
                    lane.hits.fetch_add(1, Ordering::Relaxed);
                    if let Some(ev) = bump {
                        if opts.no_bump {
                            // `u` reads leave recency state untouched
                        } else if shard.ring.push(ev) {
                            lane.bump_queued.fetch_add(1, Ordering::Relaxed);
                        } else {
                            lane.bump_dropped.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    return ReadAttempt::Hit(r);
                }
                ProbeStep::Miss => {
                    if opts.vivify.is_some() {
                        return ReadAttempt::Fallback; // create under lock
                    }
                    lane.gets.fetch_add(1, Ordering::Relaxed);
                    lane.misses.fetch_add(1, Ordering::Relaxed);
                    return ReadAttempt::Miss;
                }
                ProbeStep::Torn => {
                    lane.retries.fetch_add(1, Ordering::Relaxed);
                    std::hint::spin_loop();
                }
                ProbeStep::Unservable => {
                    lane.fallbacks.fetch_add(1, Ordering::Relaxed);
                    return ReadAttempt::Fallback;
                }
            }
        }
        lane.fallbacks.fetch_add(1, Ordering::Relaxed);
        ReadAttempt::Fallback
    }

    /// Snapshot one item's bookkeeping (the meta `me` debug command);
    /// read-locked, no LRU effects. `None` = absent or expired.
    pub fn debug_item(&self, key: &[u8]) -> Option<ItemDebug> {
        self.shards[self.shard_index(key)].read().debug_item(key)
    }

    /// Batched multiget, optimistic-first: every key is first probed
    /// **lock-free** via the seqlock protocol (the same machinery as
    /// [`get_optimistic`], including deferred LRU bumps for
    /// recency-stale hits), so on a warm cache a whole multiget touches
    /// no lock at all. Only keys the probe cannot settle — torn-read
    /// retries exhausted, expired items (lazy reclaim mutates),
    /// values ≥ [`OPTIMISTIC_VALUE_MAX`] (scatter-write hazard) — fall
    /// through to the locked pass, where they are grouped per shard and
    /// each shard's read lock is acquired **once** for its whole group
    /// (plus at most one write acquisition for expiry reclaims).
    ///
    /// `visit(ctx, request_index, value)` runs for every hit; because a
    /// lock-free probe can validate-fail *after* encoding,
    /// `unvisit(ctx, request_index)` must undo the most recent `visit`
    /// for that index (truncate the staged bytes) before the retry —
    /// the same contract as [`get_optimistic`]'s `reset`, per key.
    ///
    /// Visitation order: the optimistic pass visits in ascending
    /// request order; locked-pass hits arrive **after** it, grouped by
    /// shard. Callers that must answer in request order (the text
    /// protocol) therefore still need an order check/sort over the
    /// indices — `server::conn::do_get` stages spans and sorts only
    /// when needed.
    ///
    /// Batches of up to [`INLINE_BATCH`] keys run entirely on the
    /// stack (no allocation); longer batches spill to transient
    /// allocations.
    ///
    /// [`get_optimistic`]: ShardedStore::get_optimistic
    pub fn get_batch<C>(
        &self,
        keys: &[&[u8]],
        ctx: &mut C,
        mut visit: impl FnMut(&mut C, usize, ValueRef<'_>),
        mut unvisit: impl FnMut(&mut C, usize),
    ) {
        // pass 1: lock-free probes, in request order ------------------
        let mut pend_buf = [(0u32, 0u32); INLINE_BATCH];
        let mut pend_vec: Vec<(u32, u32)> = Vec::new();
        let mut npend = 0usize;
        for (i, key) in keys.iter().enumerate() {
            let hash = hash_key(key);
            let sidx = (mix(hash) % self.shards.len() as u64) as u32;
            let shard = &self.shards[sidx as usize];
            let lane = shard.lanes.lane();
            let mut settled = false;
            for _ in 0..OPTIMISTIC_RETRIES {
                let mut reset = |c: &mut C| unvisit(c, i);
                let mut enc =
                    |c: &mut C, _m: &ItemMeta, _now: u32, v: ValueRef<'_>| visit(c, i, v);
                match shard.probe(key, hash, ctx, &mut reset, &mut enc) {
                    ProbeStep::Hit((), bump) => {
                        lane.gets.fetch_add(1, Ordering::Relaxed);
                        lane.hits.fetch_add(1, Ordering::Relaxed);
                        if let Some(ev) = bump {
                            if shard.ring.push(ev) {
                                lane.bump_queued.fetch_add(1, Ordering::Relaxed);
                            } else {
                                lane.bump_dropped.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        settled = true;
                    }
                    ProbeStep::Miss => {
                        lane.gets.fetch_add(1, Ordering::Relaxed);
                        lane.misses.fetch_add(1, Ordering::Relaxed);
                        settled = true;
                    }
                    ProbeStep::Torn => {
                        lane.retries.fetch_add(1, Ordering::Relaxed);
                        std::hint::spin_loop();
                        continue;
                    }
                    ProbeStep::Unservable => {}
                }
                break;
            }
            if !settled {
                lane.fallbacks.fetch_add(1, Ordering::Relaxed);
                if npend < INLINE_BATCH {
                    pend_buf[npend] = (i as u32, sidx);
                } else {
                    pend_vec.push((i as u32, sidx));
                }
                npend += 1;
            }
        }
        if npend == 0 {
            return;
        }

        // pass 2: leftovers under the shard locks, grouped ------------
        let pend = |t: usize| -> (usize, u32) {
            let (i, s) = if t < INLINE_BATCH {
                pend_buf[t]
            } else {
                pend_vec[t - INLINE_BATCH]
            };
            (i as usize, s)
        };
        let mut retry_buf = [0u32; INLINE_BATCH];
        let mut retry_vec: Vec<u32> = Vec::new();
        for t in 0..npend {
            let (_, sidx) = pend(t);
            if (0..t).any(|u| pend(u).1 == sidx) {
                continue; // handled in this shard's earlier group pass
            }
            let shard = &self.shards[sidx as usize];
            let mut gets = 0u64;
            let mut hits = 0u64;
            let mut misses = 0u64;
            let mut nretry = 0usize;
            {
                let s = shard.read();
                for u in t..npend {
                    let (j, sj) = pend(u);
                    if sj != sidx {
                        continue;
                    }
                    match s.peek(keys[j], &mut |v| visit(ctx, j, v)) {
                        PeekOutcome::Hit(_) => {
                            gets += 1;
                            hits += 1;
                        }
                        PeekOutcome::Miss => {
                            gets += 1;
                            misses += 1;
                        }
                        PeekOutcome::NeedsWrite => {
                            if nretry < INLINE_BATCH {
                                retry_buf[nretry] = j as u32;
                            } else {
                                retry_vec.push(j as u32);
                            }
                            nretry += 1;
                        }
                    }
                }
            }
            if gets > 0 {
                shard.read_gets.fetch_add(gets, Ordering::Relaxed);
                shard.read_hits.fetch_add(hits, Ordering::Relaxed);
                shard.read_misses.fetch_add(misses, Ordering::Relaxed);
            }
            if nretry > 0 {
                let mut s = shard.write();
                for t2 in 0..nretry {
                    let j = if t2 < INLINE_BATCH {
                        retry_buf[t2]
                    } else {
                        retry_vec[t2 - INLINE_BATCH]
                    } as usize;
                    s.get_with(keys[j], |v| visit(ctx, j, v));
                }
                retry_vec.clear();
            }
        }
    }

    /// The unified storage primitive (see [`KvStore::meta_set`]).
    pub fn meta_set(
        &self,
        key: &[u8],
        value: &[u8],
        opts: &MetaSetOpts,
    ) -> Result<SetOutcome, StoreError> {
        self.write_shard(key).meta_set(key, value, opts)
    }

    /// Meta retrieval: zero-copy visit with per-hit metadata (TTL,
    /// last-access age, fetched bit), optional touch-on-read and
    /// vivify-on-miss ([`MetaGetOpts`]). Plain lookups (no `touch`, no
    /// `h` echo) serve recently-accessed items under the shard's *read*
    /// lock via [`KvStore::peek_meta`] — and a `u` (no-bump) read
    /// serves even recency-stale items there, since it wants no LRU
    /// mutation at all (including `h u`: with no bump the fetched bit
    /// is read-only, so the probe is a pure read). Touch, a *bumping*
    /// `h` (the fetched bit must be read and set atomically),
    /// vivify-on-miss, expired and (bumping) recency-stale items take
    /// the write path ([`KvStore::meta_get`]). `Ok(None)` = miss;
    /// `Err` = a vivify insert failed.
    pub fn meta_get<R>(
        &self,
        key: &[u8],
        opts: &MetaGetOpts,
        mut f: impl FnMut(ValueRef<'_>, MetaHit) -> R,
    ) -> Result<Option<R>, StoreError> {
        let shard = &self.shards[self.shard_index(key)];
        if opts.touch.is_none() && (!opts.wants_hit_before || opts.no_bump) {
            let s = shard.read();
            match s.peek_meta(key, opts, &mut f) {
                PeekOutcome::Hit(r) => {
                    shard.read_gets.fetch_add(1, Ordering::Relaxed);
                    shard.read_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(Some(r));
                }
                PeekOutcome::Miss if opts.vivify.is_none() => {
                    shard.read_gets.fetch_add(1, Ordering::Relaxed);
                    shard.read_misses.fetch_add(1, Ordering::Relaxed);
                    return Ok(None);
                }
                // a vivifiable miss needs the write lock to create;
                // NeedsWrite retries like get_with
                PeekOutcome::Miss | PeekOutcome::NeedsWrite => {}
            }
        }
        shard.write().meta_get(key, opts, |v, h| f(v, h))
    }

    pub fn delete(&self, key: &[u8]) -> bool {
        self.write_shard(key).delete(key)
    }

    /// CAS-guarded delete, or — with `invalidate` (meta `md ... I`) —
    /// mark-stale (see [`KvStore::delete_cas`]).
    pub fn delete_cas(&self, key: &[u8], cas: Option<u64>, invalidate: bool) -> DeleteOutcome {
        self.write_shard(key).delete_cas(key, cas, invalidate)
    }

    pub fn incr_decr(&self, key: &[u8], delta: u64, incr: bool) -> Result<Option<u64>, StoreError> {
        self.write_shard(key).incr_decr(key, delta, incr)
    }

    /// CAS-guarded, optionally vivifying arithmetic (see
    /// [`KvStore::arith`]).
    pub fn arith(&self, key: &[u8], opts: &ArithOpts) -> Result<ArithOutcome, StoreError> {
        self.write_shard(key).arith(key, opts)
    }

    pub fn touch(&self, key: &[u8], exptime: u32) -> bool {
        self.write_shard(key).touch(key, exptime)
    }

    pub fn flush_all(&self) {
        for s in &self.shards {
            s.write().flush_all();
        }
    }

    // ------------------------------------------- background maintenance

    /// Drain every shard's deferred-bump ring and apply the surviving
    /// events (stale ids/generations/CAS values are skipped) under one
    /// short write-lock lease per non-empty ring. Returns events
    /// applied. Called by the maintainer between passes — and on every
    /// pump iteration while a migration drains, so bumps stay fresh
    /// even when full maintenance is paused.
    pub fn drain_deferred(&self) -> u64 {
        let mut applied = 0u64;
        let mut buf: Vec<BumpEvent> = Vec::new();
        for s in &self.shards {
            buf.clear();
            s.ring.drain_into(&mut buf, BUMP_RING_CAP);
            if !buf.is_empty() {
                applied += s.write().apply_deferred(&buf);
            }
        }
        applied
    }

    /// One bounded maintenance pass over every shard: each shard's
    /// write lock is held only for its own ≤ `max_moves_per_shard`
    /// demotions (plus at most one slack-page release) — the
    /// maintainer thread's unit of work. Deferred read-side bumps are
    /// applied first, under the same write-lock lease, so LRU ordering
    /// is current before demotion decisions. Returns total demotions.
    pub fn maintain_all(&self, max_moves_per_shard: usize) -> usize {
        let mut demoted = 0;
        let mut buf: Vec<BumpEvent> = Vec::new();
        for s in &self.shards {
            buf.clear();
            s.ring.drain_into(&mut buf, BUMP_RING_CAP);
            let mut g = s.write();
            if !buf.is_empty() {
                g.apply_deferred(&buf);
            }
            demoted += g.maintain(max_moves_per_shard).0;
        }
        demoted
    }

    /// Run [`KvStore::check_integrity`] on every shard — the chaos
    /// suite's no-corruption oracle after each failpoint schedule.
    pub fn check_integrity(&self) -> Result<(), String> {
        for (i, s) in self.shards.iter().enumerate() {
            s.read()
                .check_integrity()
                .map_err(|e| format!("shard {i}: {e}"))?;
        }
        Ok(())
    }

    /// True when every shard's HOT/WARM fraction caps hold.
    pub fn lru_balanced(&self) -> bool {
        self.shards
            .iter()
            .all(|s| s.read().lru_balanced())
    }

    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    // ------------------------------------------------------------ stats

    /// Aggregated slab statistics across shards (whole-cache holes).
    /// Per-class rows merge by chunk size: while a migration drains,
    /// shards can expose different class tables (old + new generations,
    /// at different stages), so positional zipping would lie.
    pub fn slab_stats(&self) -> SlabStats {
        let mut shard_stats: Vec<SlabStats> = self
            .shards
            .iter()
            .map(|s| s.read().slab_stats())
            .collect();
        let mut agg = shard_stats.pop().expect("at least one shard");
        let mut by_size: BTreeMap<usize, ClassStats> = BTreeMap::new();
        let mut merge = |rows: Vec<ClassStats>| {
            for b in rows {
                match by_size.get_mut(&b.chunk_size) {
                    Some(a) => {
                        a.pages += b.pages;
                        a.total_chunks += b.total_chunks;
                        a.used_chunks += b.used_chunks;
                        a.free_chunks += b.free_chunks;
                        a.requested_bytes += b.requested_bytes;
                        a.allocated_bytes += b.allocated_bytes;
                        a.hole_bytes += b.hole_bytes;
                        a.tail_waste_bytes += b.tail_waste_bytes;
                    }
                    None => {
                        by_size.insert(b.chunk_size, b);
                    }
                }
            }
        };
        merge(std::mem::take(&mut agg.per_class));
        for st in shard_stats {
            agg.requested_bytes += st.requested_bytes;
            agg.allocated_bytes += st.allocated_bytes;
            agg.hole_bytes += st.hole_bytes;
            agg.tail_waste_bytes += st.tail_waste_bytes;
            agg.pages_allocated += st.pages_allocated;
            agg.pages_free += st.pages_free;
            agg.page_budget += st.page_budget;
            merge(st.per_class);
        }
        drop(merge);
        agg.per_class = by_size.into_values().collect();
        agg
    }

    /// Aggregated operation counters — write-path counters from each
    /// [`KvStore`] plus the shard's read-path (lock-free) get counters.
    pub fn stats(&self) -> StoreStats {
        let mut agg = StoreStats::default();
        for s in &self.shards {
            let st = s.read();
            let x = st.stats();
            agg.cmd_get += x.cmd_get;
            agg.cmd_set += x.cmd_set;
            agg.get_hits += x.get_hits;
            agg.get_misses += x.get_misses;
            agg.delete_hits += x.delete_hits;
            agg.delete_misses += x.delete_misses;
            agg.incr_hits += x.incr_hits;
            agg.incr_misses += x.incr_misses;
            agg.decr_hits += x.decr_hits;
            agg.decr_misses += x.decr_misses;
            agg.cas_hits += x.cas_hits;
            agg.cas_misses += x.cas_misses;
            agg.cas_badval += x.cas_badval;
            agg.touch_hits += x.touch_hits;
            agg.touch_misses += x.touch_misses;
            agg.evictions += x.evictions;
            agg.expired_reclaims += x.expired_reclaims;
            agg.flush_cmds += x.flush_cmds;
            agg.reconfigures += x.reconfigures;
            agg.maintainer_runs += x.maintainer_runs;
            agg.maintainer_demoted += x.maintainer_demoted;
            agg.maintainer_pages_shed += x.maintainer_pages_shed;
            agg.seqlock_retries += x.seqlock_retries;
            agg.seqlock_fallbacks += x.seqlock_fallbacks;
            agg.lru_bump_queued += x.lru_bump_queued;
            agg.lru_bump_drained += x.lru_bump_drained;
            agg.lru_bump_dropped += x.lru_bump_dropped;
            drop(st);
            agg.cmd_get += s.read_gets.load(Ordering::Relaxed);
            agg.get_hits += s.read_hits.load(Ordering::Relaxed);
            agg.get_misses += s.read_misses.load(Ordering::Relaxed);
            let lt = s.lanes.totals();
            agg.cmd_get += lt.gets;
            agg.get_hits += lt.hits;
            agg.get_misses += lt.misses;
            agg.seqlock_retries += lt.retries;
            agg.seqlock_fallbacks += lt.fallbacks;
            agg.lru_bump_queued += lt.bump_queued;
            agg.lru_bump_dropped += lt.bump_dropped;
        }
        agg
    }

    /// `stats reset`: zero every shard's cumulative operation counters
    /// and the lock-free read-path counters. Gauges (item counts, slab
    /// geometry) are untouched.
    pub fn reset_stats(&self) {
        for s in &self.shards {
            s.write().reset_stats();
            s.read_gets.store(0, Ordering::Relaxed);
            s.read_hits.store(0, Ordering::Relaxed);
            s.read_misses.store(0, Ordering::Relaxed);
            s.lanes.reset();
        }
        // per-tenant cumulative counters reset too; registry rules and
        // the live byte/item gauges survive (they mirror residency)
        self.tenants.reset_counters();
    }

    /// Current chunk-size table (identical across shards —
    /// [`begin_reconfigure`] switches all shards atomically).
    ///
    /// [`begin_reconfigure`]: ShardedStore::begin_reconfigure
    pub fn chunk_sizes(&self) -> Vec<usize> {
        self.shards[0].read().chunk_sizes().to_vec()
    }

    // ---------------------------------------------------- warm restart

    /// The page size every shard's allocator carves (a construction
    /// constant; the manifest persists it as part of the geometry).
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// The mmap-backed page region, when `--memory-file` is active.
    pub fn region(&self) -> Option<&SlabRegion> {
        self.region.as_ref()
    }

    /// Write guard on shard `i` — the restart module's door into each
    /// shard for manifest export and recovery restore.
    pub(crate) fn shard_write(&self, i: usize) -> RwLockWriteGuard<'_, KvStore> {
        self.shards[i].write()
    }

    /// Read guard on shard `i` (manifest export).
    pub(crate) fn shard_read(&self, i: usize) -> RwLockReadGuard<'_, KvStore> {
        self.shards[i].read()
    }

    /// Record how this boot obtained its contents (set once by the
    /// restart module during startup).
    pub(crate) fn set_restart(
        &self,
        state: u8,
        reason: &str,
        items_recovered: u64,
        items_discarded: u64,
        duration_ms: u64,
    ) {
        self.restart.state.store(state, Ordering::Relaxed);
        self.restart
            .items_recovered
            .store(items_recovered, Ordering::Relaxed);
        self.restart
            .items_discarded
            .store(items_discarded, Ordering::Relaxed);
        self.restart
            .duration_ms
            .store(duration_ms, Ordering::Relaxed);
        if let Ok(mut r) = self.restart.reason.lock() {
            r.clear();
            r.push_str(reason);
        }
    }

    /// The `restart_*` gauge block for `stats`. Boot-scoped: survives
    /// `stats reset` and `flush_all` by design (see module docs on the
    /// recovery-counter contract).
    pub fn restart_snapshot(&self) -> RestartSnapshot {
        RestartSnapshot {
            state: match self.restart.state.load(Ordering::Relaxed) {
                1 => "warm",
                2 => "cold",
                _ => "disabled",
            },
            reason: self
                .restart
                .reason
                .lock()
                .map(|r| r.clone())
                .unwrap_or_default(),
            items_recovered: self.restart.items_recovered.load(Ordering::Relaxed),
            items_discarded: self.restart.items_discarded.load(Ordering::Relaxed),
            duration_ms: self.restart.duration_ms.load(Ordering::Relaxed),
        }
    }

    // ------------------------------------------- live reconfiguration

    /// Kick off an incremental migration to a new chunk geometry on
    /// every shard. The policy is validated **once, up front**, and the
    /// generation flip happens with all shard locks held (an O(shards)
    /// pause — no item is touched), so a failure can never leave shards
    /// on divergent geometries. Returns immediately; the drain is
    /// driven by [`migration_step_all`] (the auto-tuner's background
    /// thread, or any caller polling).
    ///
    /// [`migration_step_all`]: ShardedStore::migration_step_all
    pub fn begin_reconfigure(&self, policy: ChunkSizePolicy) -> Result<(), StoreError> {
        policy
            .materialize(self.page_size)
            .map_err(|e| StoreError::BadPolicy(e.to_string()))?;
        let mut guards: Vec<RwLockWriteGuard<'_, KvStore>> = self
            .shards
            .iter()
            .map(|s| s.write())
            .collect();
        if guards.iter().any(|g| g.migration_active()) {
            return Err(StoreError::Busy);
        }
        for g in &mut guards {
            g.begin_migration(policy.clone())
                .expect("validated policy and idle shard cannot fail");
        }
        Ok(())
    }

    /// Drive every shard's drain by one bounded step (`migrate_batch`
    /// items max per shard, each under that shard's write lock only for
    /// the step). Returns `true` while any shard is still draining.
    pub fn migration_step_all(&self) -> bool {
        let batch = self.migrate_batch();
        let mut active = false;
        for s in &self.shards {
            active |= s.write().migrate_step(batch);
        }
        active
    }

    /// True while any shard has a drain in flight.
    pub fn migration_active(&self) -> bool {
        self.shards
            .iter()
            .any(|s| s.read().migration_active())
    }

    /// Aggregated migration gauges (`stats slabs`).
    pub fn migration_gauges(&self) -> MigrationGauges {
        let mut agg = MigrationGauges::default();
        for s in &self.shards {
            let g = s.read().migration_gauges();
            agg.active_shards += g.active_shards;
            agg.moved += g.moved;
            agg.dropped += g.dropped;
            agg.pages_reclaimed += g.pages_reclaimed;
            agg.force_drained_pages += g.force_drained_pages;
            agg.force_dropped += g.force_dropped;
            agg.items_remaining += g.items_remaining;
        }
        agg
    }

    /// Reconfigure every shard and drive the drain to completion —
    /// the blocking convenience over [`begin_reconfigure`] +
    /// [`migration_step_all`]. Unlike the old stop-the-world migration,
    /// each shard's write lock is held only per bounded step, so
    /// concurrent traffic keeps serving throughout.
    ///
    /// [`begin_reconfigure`]: ShardedStore::begin_reconfigure
    /// [`migration_step_all`]: ShardedStore::migration_step_all
    pub fn reconfigure(&self, policy: ChunkSizePolicy) -> Result<Vec<MigrationReport>, StoreError> {
        self.begin_reconfigure(policy)?;
        while self.migration_step_all() {
            // let concurrent readers win the lock between rounds
            std::thread::yield_now();
        }
        Ok(self
            .shards
            .iter()
            .map(|s| {
                s.read()
                    .last_migration()
                    .cloned()
                    .expect("drain just completed")
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::PAGE_SIZE;
    use crate::store::item::total_item_size;

    fn store(shards: usize) -> ShardedStore {
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            64 << 20,
            true,
            shards,
            Clock::System,
        )
        .unwrap()
    }

    #[test]
    fn routes_consistently() {
        let s = store(4);
        for i in 0..500u32 {
            let k = format!("key-{i}");
            s.set(k.as_bytes(), k.as_bytes(), 0, 0).unwrap();
        }
        assert_eq!(s.len(), 500);
        for i in 0..500u32 {
            let k = format!("key-{i}");
            assert_eq!(s.get(k.as_bytes()).unwrap().value, k.as_bytes());
        }
    }

    #[test]
    fn shards_spread_keys() {
        let s = store(4);
        for i in 0..2000u32 {
            s.set(format!("k{i}").as_bytes(), b"v", 0, 0).unwrap();
        }
        let per: Vec<usize> = s
            .shards
            .iter()
            .map(|x| x.read().len())
            .collect();
        assert!(per.iter().all(|&n| n > 300), "uneven shards: {per:?}");
    }

    #[test]
    fn shards_spread_keys_at_64_shards() {
        // the old `hash >> 56` routing had only 256 distinct routes;
        // at 64 shards that is 4 routes per shard on average, and any
        // non-uniformity in the top byte lands whole key families on
        // one shard. The fold must keep every shard near the mean.
        let s = store(64);
        let n = 64_000u32;
        for i in 0..n {
            s.set(format!("user:{i:06}").as_bytes(), b"v", 0, 0).unwrap();
        }
        let per: Vec<usize> = s
            .shards
            .iter()
            .map(|x| x.read().len())
            .collect();
        let mean = n as usize / 64;
        let (lo, hi) = (mean / 2, mean * 2);
        assert!(
            per.iter().all(|&c| c > lo && c < hi),
            "shard spread outside [{lo}, {hi}]: {per:?}"
        );
    }

    #[test]
    fn aggregated_hole_accounting() {
        let s = store(4);
        let vlen = 455usize; // total 518 with 5-byte key
        for i in 0..1000u32 {
            s.set(format!("k{i:03}").as_bytes(), &vec![b'x'; vlen - 1], 0, 0)
                .unwrap();
        }
        let expected_total = total_item_size(4, vlen - 1, true) as u64 * 1000;
        let st = s.slab_stats();
        assert_eq!(st.requested_bytes, expected_total);
        assert!(st.hole_bytes > 0);
        assert_eq!(
            st.allocated_bytes - st.requested_bytes,
            st.hole_bytes
        );
    }

    #[test]
    fn reconfigure_all_shards() {
        let s = store(2);
        for i in 0..400u32 {
            s.set(format!("k{i:04}").as_bytes(), &vec![b'x'; 455], 0, 0)
                .unwrap();
        }
        let reports = s
            .reconfigure(ChunkSizePolicy::Explicit(vec![518]))
            .unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports.iter().map(|r| r.items_moved).sum::<usize>(), 400);
        assert_eq!(s.slab_stats().hole_bytes, 0);
        assert_eq!(s.get(b"k0000").unwrap().value.len(), 455);
    }

    #[test]
    fn begin_reconfigure_validates_before_touching_shards() {
        // a bad policy must fail up front: no shard may flip geometry
        // (the old per-shard loop left shards 0..k migrated on error)
        let s = store(4);
        s.set(b"k", &vec![b'x'; 400], 0, 0).unwrap();
        let before = s.chunk_sizes();
        match s.begin_reconfigure(ChunkSizePolicy::Explicit(vec![900, 400])) {
            Err(StoreError::BadPolicy(_)) => {}
            other => panic!("{other:?}"),
        }
        assert!(!s.migration_active());
        assert_eq!(s.chunk_sizes(), before);
        assert_eq!(s.get(b"k").unwrap().value.len(), 400);
    }

    #[test]
    fn gets_served_between_sharded_migration_steps() {
        let s = store(2);
        for i in 0..2000u32 {
            s.set(format!("k{i:04}").as_bytes(), &vec![b'x'; 455], 0, 0)
                .unwrap();
        }
        s.set_migrate_batch(64);
        s.begin_reconfigure(ChunkSizePolicy::Explicit(vec![518]))
            .unwrap();
        assert!(s.migration_active());
        let mut rounds = 0;
        while s.migration_step_all() {
            rounds += 1;
            // the store serves normally between steps, both generations
            assert_eq!(s.get(b"k0000").unwrap().value.len(), 455);
            assert_eq!(s.get(b"k1999").unwrap().value.len(), 455);
            // exact-fit mid-drain writes keep the hole assertion exact
            s.set(format!("m{rounds:04}").as_bytes(), &vec![b'y'; 455], 0, 0)
                .unwrap();
        }
        assert!(rounds > 1, "drain must span multiple steps");
        assert!(!s.migration_active());
        let g = s.migration_gauges();
        assert_eq!(g.moved, 2000);
        assert_eq!(g.dropped, 0);
        assert_eq!(s.slab_stats().hole_bytes, 0);
    }

    #[test]
    fn second_reconfigure_while_draining_is_busy() {
        let s = store(2);
        for i in 0..500u32 {
            s.set(format!("k{i:03}").as_bytes(), &vec![b'x'; 455], 0, 0)
                .unwrap();
        }
        s.begin_reconfigure(ChunkSizePolicy::Explicit(vec![518]))
            .unwrap();
        assert!(matches!(
            s.begin_reconfigure(ChunkSizePolicy::Explicit(vec![600])),
            Err(StoreError::Busy)
        ));
        while s.migration_step_all() {}
        s.begin_reconfigure(ChunkSizePolicy::Explicit(vec![600]))
            .unwrap();
        while s.migration_step_all() {}
    }

    #[test]
    fn stats_aggregate() {
        let s = store(3);
        s.set(b"a", b"1", 0, 0).unwrap();
        s.get(b"a");
        s.get(b"missing");
        let st = s.stats();
        assert_eq!(st.cmd_set, 1);
        assert_eq!(st.get_hits, 1);
        assert_eq!(st.get_misses, 1);
        assert_eq!(st.cmd_get, 2);
    }

    #[test]
    fn single_shard_works() {
        let s = store(1);
        s.set(b"k", b"v", 0, 0).unwrap();
        assert_eq!(s.get(b"k").unwrap().value, b"v");
    }

    #[test]
    fn get_with_zero_copy_visitor() {
        let s = store(2);
        s.set(b"k", b"payload", 5, 0).unwrap();
        let got = s.get_with(b"k", |v: ValueRef<'_>| (v.data.to_vec(), v.flags));
        let (data, flags) = got.unwrap();
        assert_eq!(data, b"payload");
        assert_eq!(flags, 5);
        assert!(s.get_with(b"missing", |_: ValueRef<'_>| ()).is_none());
    }

    #[test]
    fn get_batch_visits_hits_with_request_indices() {
        let s = store(8);
        let keys: Vec<String> = (0..40).map(|i| format!("batch-{i:02}")).collect();
        for (i, k) in keys.iter().enumerate() {
            if i % 3 != 0 {
                s.set(k.as_bytes(), format!("v{i}").as_bytes(), 0, 0).unwrap();
            }
        }
        let refs: Vec<&[u8]> = keys.iter().map(|k| k.as_bytes()).collect();
        let mut seen: Vec<(usize, Vec<u8>)> = Vec::new();
        s.get_batch(
            &refs,
            &mut seen,
            |c, idx, v| c.push((idx, v.data.to_vec())),
            |c, idx| {
                if c.last().is_some_and(|(i, _)| *i == idx) {
                    c.pop();
                }
            },
        );
        // every stored key visited exactly once, with the right bytes
        let mut got: Vec<usize> = seen.iter().map(|(i, _)| *i).collect();
        got.sort_unstable();
        let want: Vec<usize> = (0..40).filter(|i| i % 3 != 0).collect();
        assert_eq!(got, want);
        for (i, data) in &seen {
            assert_eq!(data, format!("v{i}").as_bytes());
        }
        // misses counted
        assert_eq!(s.stats().get_misses, 14); // ceil(40/3)
        assert_eq!(s.stats().get_hits, 26);
    }

    #[test]
    fn get_batch_orders_within_shard_and_groups_across() {
        let s = store(4);
        let keys: Vec<String> = (0..32).map(|i| format!("ord-{i:02}")).collect();
        for k in &keys {
            s.set(k.as_bytes(), k.as_bytes(), 0, 0).unwrap();
        }
        let refs: Vec<&[u8]> = keys.iter().map(|k| k.as_bytes()).collect();
        let mut order: Vec<usize> = Vec::new();
        s.get_batch(
            &refs,
            &mut order,
            |c, idx, _| c.push(idx),
            |c, idx| {
                if c.last() == Some(&idx) {
                    c.pop();
                }
            },
        );
        assert_eq!(order.len(), 32);
        // hits from one shard must arrive in ascending request order
        let shard_of: Vec<usize> = refs.iter().map(|k| s.shard_index(k)).collect();
        for sh in 0..4 {
            let per: Vec<usize> = order
                .iter()
                .copied()
                .filter(|&i| shard_of[i] == sh)
                .collect();
            assert!(per.windows(2).all(|w| w[0] < w[1]), "shard {sh}: {per:?}");
        }
    }

    #[test]
    fn get_batch_retries_stale_items_on_write_path() {
        let (clock, cell) = Clock::manual(5_000_000);
        let s = ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            16 << 20,
            true,
            2,
            clock,
        )
        .unwrap();
        s.set(b"a", b"1", 0, 0).unwrap();
        s.set(b"b", b"2", 0, 100).unwrap();
        // push both items past TOUCH_INTERVAL, and "b" past its expiry:
        // "a" is served lock-free with a deferred bump; "b" is
        // unservable optimistically and the locked retry reclaims it
        cell.store(5_000_000 + 120, Ordering::Relaxed);
        let mut seen = Vec::new();
        s.get_batch(
            &[b"a".as_slice(), b"b".as_slice()],
            &mut seen,
            |c, idx, v| c.push((idx, v.data.to_vec())),
            |c, idx| {
                if c.last().is_some_and(|(i, _)| *i == idx) {
                    c.pop();
                }
            },
        );
        assert_eq!(seen, vec![(0usize, b"1".to_vec())]);
        assert_eq!(s.stats().expired_reclaims, 1);
        assert_eq!(s.stats().lru_bump_queued, 1, "stale hit deferred its bump");
    }

    #[test]
    fn concurrent_reads_one_shard() {
        let s = Arc::new(store(1));
        s.set(b"hotkey", b"hotvalue", 0, 0).unwrap();
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let s = s.clone();
                std::thread::spawn(move || {
                    for _ in 0..2000 {
                        let ok = s
                            .get_with(b"hotkey", |v: ValueRef<'_>| v.data == b"hotvalue")
                            .unwrap();
                        assert!(ok);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(s.stats().get_hits, 16_000);
    }

    #[test]
    fn meta_get_serves_reads_and_vivifies() {
        use crate::store::store::MetaHit;
        let s = store(4);
        let plain = MetaGetOpts::default();
        s.set(b"k", b"val", 9, 0).unwrap();
        // fresh item: read path, ttl -1
        let got = s.meta_get(b"k", &plain, |v: ValueRef<'_>, h: MetaHit| {
            (v.data.to_vec(), v.flags, h.ttl, h.won)
        });
        assert_eq!(got.unwrap(), Some((b"val".to_vec(), 9, -1, false)));
        assert_eq!(s.stats().get_hits, 1, "read-path hit counted");
        // miss without vivify counted on the read path
        assert!(s
            .meta_get(b"nope", &plain, |_: ValueRef<'_>, _| ())
            .unwrap()
            .is_none());
        assert_eq!(s.stats().get_misses, 1);
        // vivify creates through the write path
        let viv = MetaGetOpts {
            vivify: Some(60),
            ..MetaGetOpts::default()
        };
        let h = s
            .meta_get(b"viv", &viv, |_: ValueRef<'_>, h| h)
            .unwrap()
            .unwrap();
        assert!(h.won);
        assert_eq!(s.get(b"viv").unwrap().value, b"");
        // touch-on-read goes straight to the write path
        let touch = MetaGetOpts {
            touch: Some(120),
            ..MetaGetOpts::default()
        };
        let h = s
            .meta_get(b"k", &touch, |_: ValueRef<'_>, h| h)
            .unwrap()
            .unwrap();
        assert_eq!(h.ttl, 120);
    }

    #[test]
    fn optimistic_get_hit_and_miss() {
        let s = store(2);
        s.set(b"opt", b"payload", 7, 0).unwrap();
        let mut buf: Vec<u8> = Vec::new();
        let got = s.get_optimistic(
            b"opt",
            &mut buf,
            |c| c.clear(),
            |c, v| {
                c.extend_from_slice(v.data);
                v.flags
            },
        );
        match got {
            ReadAttempt::Hit(flags) => {
                assert_eq!(flags, 7);
                assert_eq!(buf, b"payload");
            }
            _ => panic!("expected lock-free hit"),
        }
        buf.clear();
        assert!(matches!(
            s.get_optimistic(b"nope", &mut buf, |c| c.clear(), |_, _: ValueRef<'_>| ()),
            ReadAttempt::Miss
        ));
        let st = s.stats();
        assert_eq!((st.cmd_get, st.get_hits, st.get_misses), (2, 1, 1));
        assert_eq!(st.seqlock_retries, 0);
        assert_eq!(st.seqlock_fallbacks, 0);
    }

    #[test]
    fn optimistic_get_defers_lru_bump() {
        let (clock, cell) = Clock::manual(5_000_000);
        let s = ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            16 << 20,
            true,
            2,
            clock,
        )
        .unwrap();
        s.set(b"k", b"v", 0, 0).unwrap();
        // push the item past TOUCH_INTERVAL: the hit must still be
        // served lock-free, with the bump queued rather than applied
        cell.store(5_000_000 + TOUCH_INTERVAL + 5, Ordering::Relaxed);
        let mut buf: Vec<u8> = Vec::new();
        assert!(matches!(
            s.get_optimistic(b"k", &mut buf, |c| c.clear(), |_, _: ValueRef<'_>| ()),
            ReadAttempt::Hit(())
        ));
        let st = s.stats();
        assert_eq!(st.lru_bump_queued, 1);
        assert_eq!(st.lru_bump_drained, 0);
        // before the drain the write-path bookkeeping is untouched
        let d = s.debug_item(b"k").unwrap();
        assert_eq!(d.la, TOUCH_INTERVAL + 5);
        assert!(!d.fetched);
        assert_eq!(s.drain_deferred(), 1);
        assert_eq!(s.stats().lru_bump_drained, 1);
        let d = s.debug_item(b"k").unwrap();
        assert_eq!(d.la, 0, "deferred bump refreshed the access time");
        assert!(d.fetched, "deferred bump set the fetched bit");
        // a second drain finds an empty ring
        assert_eq!(s.drain_deferred(), 0);
    }

    #[test]
    fn optimistic_get_falls_back_for_large_values() {
        let s = store(1);
        s.set(b"big", &vec![b'x'; OPTIMISTIC_VALUE_MAX], 0, 0).unwrap();
        let mut buf: Vec<u8> = Vec::new();
        assert!(matches!(
            s.get_optimistic(b"big", &mut buf, |c| c.clear(), |_, _: ValueRef<'_>| ()),
            ReadAttempt::Fallback
        ));
        assert!(buf.is_empty(), "no bytes encoded on fallback");
        let st = s.stats();
        assert_eq!(st.seqlock_fallbacks, 1);
        assert_eq!(st.get_hits, 0, "fallback does not count the get");
        // the locked path then serves it (and counts it)
        assert_eq!(
            s.get_with(b"big", |v: ValueRef<'_>| v.data.len()).unwrap(),
            OPTIMISTIC_VALUE_MAX
        );
        assert_eq!(s.stats().get_hits, 1);
    }

    #[test]
    fn optimistic_get_falls_back_on_expired() {
        let (clock, cell) = Clock::manual(5_000_000);
        let s = ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            16 << 20,
            true,
            2,
            clock,
        )
        .unwrap();
        s.set(b"e", b"v", 0, 50).unwrap();
        cell.store(5_000_000 + 120, Ordering::Relaxed);
        let mut buf: Vec<u8> = Vec::new();
        assert!(matches!(
            s.get_optimistic(b"e", &mut buf, |c| c.clear(), |_, _: ValueRef<'_>| ()),
            ReadAttempt::Fallback
        ));
        assert_eq!(s.stats().seqlock_fallbacks, 1);
        // the locked retry performs the lazy reclaim
        assert!(s.get(b"e").is_none());
        assert_eq!(s.stats().expired_reclaims, 1);
    }

    #[test]
    fn meta_get_optimistic_echoes_and_gates() {
        let s = store(2);
        s.set(b"k", b"val", 9, 0).unwrap();
        let plain = MetaGetOpts::default();
        let mut buf: Vec<u8> = Vec::new();
        let got = s.meta_get_optimistic(
            b"k",
            &plain,
            &mut buf,
            |c| c.clear(),
            |c, v, h| {
                c.extend_from_slice(v.data);
                (v.flags, h.ttl, h.la, h.fetched, h.won)
            },
        );
        match got {
            ReadAttempt::Hit(echo) => {
                assert_eq!(echo, (9, -1, 0, false, false));
                assert_eq!(buf, b"val");
            }
            _ => panic!("expected lock-free meta hit"),
        }
        // plain miss resolves lock-free
        buf.clear();
        assert!(matches!(
            s.meta_get_optimistic(b"nope", &plain, &mut buf, |c| c.clear(), |_, _: ValueRef<'_>, _| ()),
            ReadAttempt::Miss
        ));
        // touch-on-read must take the write path (uncounted fallback)
        let touch = MetaGetOpts {
            touch: Some(120),
            ..MetaGetOpts::default()
        };
        assert!(matches!(
            s.meta_get_optimistic(b"k", &touch, &mut buf, |c| c.clear(), |_, _: ValueRef<'_>, _| ()),
            ReadAttempt::Fallback
        ));
        // vivifiable miss must create under the lock (uncounted fallback)
        let viv = MetaGetOpts {
            vivify: Some(60),
            ..MetaGetOpts::default()
        };
        assert!(matches!(
            s.meta_get_optimistic(b"viv", &viv, &mut buf, |c| c.clear(), |_, _: ValueRef<'_>, _| ()),
            ReadAttempt::Fallback
        ));
        let st = s.stats();
        assert_eq!(st.seqlock_fallbacks, 0, "protocol-shape fallbacks uncounted");
        assert_eq!((st.cmd_get, st.get_hits, st.get_misses), (2, 1, 1));
    }

    #[test]
    fn meta_get_optimistic_falls_back_on_stale() {
        let s = store(2);
        s.set(b"k", b"val", 0, 0).unwrap();
        assert_eq!(s.delete_cas(b"k", None, true), DeleteOutcome::Deleted);
        let plain = MetaGetOpts::default();
        let mut buf: Vec<u8> = Vec::new();
        // the probe reaches the item, sees the stale bit in the
        // validated copy, undoes its encode and falls back (counted)
        assert!(matches!(
            s.meta_get_optimistic(
                b"k",
                &plain,
                &mut buf,
                |c| c.clear(),
                |c, v, _| c.extend_from_slice(v.data)
            ),
            ReadAttempt::Fallback
        ));
        assert!(buf.is_empty(), "staged stale encode undone");
        assert_eq!(s.stats().seqlock_fallbacks, 1);
        // an `R` request is a protocol-shape fallback (uncounted)
        let r = MetaGetOpts {
            recache: Some(30),
            ..MetaGetOpts::default()
        };
        assert!(matches!(
            s.meta_get_optimistic(b"k", &r, &mut buf, |c| c.clear(), |_, _: ValueRef<'_>, _| ()),
            ReadAttempt::Fallback
        ));
        assert_eq!(s.stats().seqlock_fallbacks, 1, "R gate is uncounted");
        // the locked path then runs the win race over the stale value
        let h = s.meta_get(b"k", &plain, |_, h| h).unwrap().unwrap();
        assert!(h.stale && h.won && !h.lost);
        let h = s.meta_get(b"k", &plain, |_, h| h).unwrap().unwrap();
        assert!(h.stale && !h.won && h.lost);
    }

    #[test]
    fn get_batch_serves_fresh_keys_lock_free() {
        let s = store(4);
        for i in 0..20u32 {
            s.set(format!("lf-{i:02}").as_bytes(), b"v", 0, 0).unwrap();
        }
        let keys: Vec<String> = (0..24).map(|i| format!("lf-{i:02}")).collect();
        let refs: Vec<&[u8]> = keys.iter().map(|k| k.as_bytes()).collect();
        let mut n = 0usize;
        s.get_batch(&refs, &mut n, |c, _, _| *c += 1, |c, _| *c -= 1);
        assert_eq!(n, 20);
        let st = s.stats();
        assert_eq!((st.get_hits, st.get_misses), (20, 4));
        assert_eq!(st.seqlock_fallbacks, 0, "fresh batch never takes a lock");
    }

    #[test]
    fn reset_stats_covers_both_paths() {
        let s = store(2);
        s.set(b"a", b"1", 0, 0).unwrap();
        s.get(b"a"); // read path
        s.get(b"missing");
        s.delete(b"a");
        let st = s.stats();
        assert!(st.cmd_get >= 2 && st.cmd_set >= 1 && st.delete_hits == 1);
        s.reset_stats();
        let st = s.stats();
        assert_eq!(
            (st.cmd_get, st.cmd_set, st.get_hits, st.get_misses, st.delete_hits),
            (0, 0, 0, 0, 0)
        );
    }
}
