//! Shard router: N independent [`KvStore`]s behind per-shard mutexes
//! (memcached's item-lock striping, coarsened to whole shards). Keys
//! route by the top bits of their hash, disjoint from the bucket-index
//! bits the per-shard hash tables use.

use super::item::hash_key;
use super::store::{CasResult, Clock, KvStore, MigrationReport, SizeObserver, StoreError, StoreStats, Value};
use crate::config::Settings;
use crate::slab::policy::ChunkSizePolicy;
use crate::slab::{SlabError, SlabStats};
use std::sync::{Arc, Mutex, MutexGuard};

/// Thread-safe sharded cache — the object the TCP server serves.
pub struct ShardedStore {
    shards: Vec<Mutex<KvStore>>,
}

impl ShardedStore {
    /// Build from [`Settings`] (shard count, memory split, policy).
    pub fn new(settings: &Settings) -> Result<Self, SlabError> {
        Self::with(
            settings.policy.clone(),
            settings.page_size,
            settings.mem_limit,
            settings.use_cas,
            settings.shards,
            Clock::System,
        )
    }

    /// Fully explicit constructor (tests, benches).
    pub fn with(
        policy: ChunkSizePolicy,
        page_size: usize,
        mem_limit: usize,
        use_cas: bool,
        shards: usize,
        clock: Clock,
    ) -> Result<Self, SlabError> {
        assert!(shards > 0);
        let per_shard = (mem_limit / shards).max(page_size);
        let stores: Result<Vec<_>, SlabError> = (0..shards)
            .map(|_| {
                KvStore::new(policy.clone(), page_size, per_shard, use_cas, clock.clone())
                    .map(Mutex::new)
            })
            .collect();
        Ok(ShardedStore { shards: stores? })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    #[inline]
    fn shard_for(&self, key: &[u8]) -> MutexGuard<'_, KvStore> {
        // top byte of the hash — independent of the table's low bits
        let idx = (hash_key(key) >> 56) as usize % self.shards.len();
        self.shards[idx].lock().unwrap()
    }

    /// Attach a size observer to every shard.
    pub fn set_observer(&self, obs: Arc<dyn SizeObserver>) {
        for s in &self.shards {
            s.lock().unwrap().set_observer(obs.clone());
        }
    }

    // ------------------------------------------------------------- ops

    pub fn set(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> Result<(), StoreError> {
        self.shard_for(key).set(key, value, flags, exptime)
    }

    pub fn add(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> Result<bool, StoreError> {
        self.shard_for(key).add(key, value, flags, exptime)
    }

    pub fn replace(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32) -> Result<bool, StoreError> {
        self.shard_for(key).replace(key, value, flags, exptime)
    }

    pub fn cas(&self, key: &[u8], value: &[u8], flags: u32, exptime: u32, cas: u64) -> Result<CasResult, StoreError> {
        self.shard_for(key).cas(key, value, flags, exptime, cas)
    }

    pub fn concat(&self, key: &[u8], data: &[u8], append: bool) -> Result<bool, StoreError> {
        self.shard_for(key).concat(key, data, append)
    }

    pub fn get(&self, key: &[u8]) -> Option<Value> {
        self.shard_for(key).get(key)
    }

    pub fn delete(&self, key: &[u8]) -> bool {
        self.shard_for(key).delete(key)
    }

    pub fn incr_decr(&self, key: &[u8], delta: u64, incr: bool) -> Result<Option<u64>, StoreError> {
        self.shard_for(key).incr_decr(key, delta, incr)
    }

    pub fn touch(&self, key: &[u8], exptime: u32) -> bool {
        self.shard_for(key).touch(key, exptime)
    }

    pub fn flush_all(&self) {
        for s in &self.shards {
            s.lock().unwrap().flush_all();
        }
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    // ------------------------------------------------------------ stats

    /// Aggregated slab statistics across shards (whole-cache holes).
    pub fn slab_stats(&self) -> SlabStats {
        let mut shard_stats: Vec<SlabStats> =
            self.shards.iter().map(|s| s.lock().unwrap().slab_stats()).collect();
        let mut agg = shard_stats.pop().expect("at least one shard");
        for st in shard_stats {
            agg.requested_bytes += st.requested_bytes;
            agg.allocated_bytes += st.allocated_bytes;
            agg.hole_bytes += st.hole_bytes;
            agg.tail_waste_bytes += st.tail_waste_bytes;
            agg.pages_allocated += st.pages_allocated;
            agg.page_budget += st.page_budget;
            for (a, b) in agg.per_class.iter_mut().zip(st.per_class.iter()) {
                debug_assert_eq!(a.chunk_size, b.chunk_size, "shards share a policy");
                a.pages += b.pages;
                a.total_chunks += b.total_chunks;
                a.used_chunks += b.used_chunks;
                a.free_chunks += b.free_chunks;
                a.requested_bytes += b.requested_bytes;
                a.allocated_bytes += b.allocated_bytes;
                a.hole_bytes += b.hole_bytes;
                a.tail_waste_bytes += b.tail_waste_bytes;
            }
        }
        agg
    }

    /// Aggregated operation counters.
    pub fn stats(&self) -> StoreStats {
        let mut agg = StoreStats::default();
        for s in &self.shards {
            let st = s.lock().unwrap();
            let x = st.stats();
            agg.cmd_get += x.cmd_get;
            agg.cmd_set += x.cmd_set;
            agg.get_hits += x.get_hits;
            agg.get_misses += x.get_misses;
            agg.delete_hits += x.delete_hits;
            agg.delete_misses += x.delete_misses;
            agg.incr_hits += x.incr_hits;
            agg.incr_misses += x.incr_misses;
            agg.decr_hits += x.decr_hits;
            agg.decr_misses += x.decr_misses;
            agg.cas_hits += x.cas_hits;
            agg.cas_misses += x.cas_misses;
            agg.cas_badval += x.cas_badval;
            agg.touch_hits += x.touch_hits;
            agg.touch_misses += x.touch_misses;
            agg.evictions += x.evictions;
            agg.expired_reclaims += x.expired_reclaims;
            agg.flush_cmds += x.flush_cmds;
            agg.reconfigures += x.reconfigures;
        }
        agg
    }

    /// Current chunk-size table (identical across shards).
    pub fn chunk_sizes(&self) -> Vec<usize> {
        self.shards[0].lock().unwrap().chunk_sizes().to_vec()
    }

    /// Reconfigure every shard to a new chunk geometry, shard by shard
    /// (bounds the transient extra memory to one shard's worth).
    pub fn reconfigure(&self, policy: ChunkSizePolicy) -> Result<Vec<MigrationReport>, StoreError> {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().reconfigure(policy.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::PAGE_SIZE;
    use crate::store::item::total_item_size;

    fn store(shards: usize) -> ShardedStore {
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            64 << 20,
            true,
            shards,
            Clock::System,
        )
        .unwrap()
    }

    #[test]
    fn routes_consistently() {
        let s = store(4);
        for i in 0..500u32 {
            let k = format!("key-{i}");
            s.set(k.as_bytes(), k.as_bytes(), 0, 0).unwrap();
        }
        assert_eq!(s.len(), 500);
        for i in 0..500u32 {
            let k = format!("key-{i}");
            assert_eq!(s.get(k.as_bytes()).unwrap().value, k.as_bytes());
        }
    }

    #[test]
    fn shards_spread_keys() {
        let s = store(4);
        for i in 0..2000u32 {
            s.set(format!("k{i}").as_bytes(), b"v", 0, 0).unwrap();
        }
        let per: Vec<usize> = s.shards.iter().map(|x| x.lock().unwrap().len()).collect();
        assert!(per.iter().all(|&n| n > 300), "uneven shards: {per:?}");
    }

    #[test]
    fn aggregated_hole_accounting() {
        let s = store(4);
        let vlen = 455usize; // total 518 with 5-byte key
        for i in 0..1000u32 {
            s.set(format!("k{i:03}").as_bytes(), &vec![b'x'; vlen - 1], 0, 0)
                .unwrap();
        }
        let expected_total = total_item_size(4, vlen - 1, true) as u64 * 1000;
        let st = s.slab_stats();
        assert_eq!(st.requested_bytes, expected_total);
        assert!(st.hole_bytes > 0);
        assert_eq!(
            st.allocated_bytes - st.requested_bytes,
            st.hole_bytes
        );
    }

    #[test]
    fn reconfigure_all_shards() {
        let s = store(2);
        for i in 0..400u32 {
            s.set(format!("k{i:04}").as_bytes(), &vec![b'x'; 455], 0, 0)
                .unwrap();
        }
        let reports = s
            .reconfigure(ChunkSizePolicy::Explicit(vec![518]))
            .unwrap();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports.iter().map(|r| r.items_moved).sum::<usize>(), 400);
        assert_eq!(s.slab_stats().hole_bytes, 0);
        assert_eq!(s.get(b"k0000").unwrap().value.len(), 455);
    }

    #[test]
    fn stats_aggregate() {
        let s = store(3);
        s.set(b"a", b"1", 0, 0).unwrap();
        s.get(b"a");
        s.get(b"missing");
        let st = s.stats();
        assert_eq!(st.cmd_set, 1);
        assert_eq!(st.get_hits, 1);
        assert_eq!(st.get_misses, 1);
    }

    #[test]
    fn single_shard_works() {
        let s = store(1);
        s.set(b"k", b"v", 0, 0).unwrap();
        assert_eq!(s.get(b"k").unwrap().value, b"v");
    }
}
