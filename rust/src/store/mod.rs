//! The key-value engine on top of the slab allocator: memcached's item
//! accounting, chained hash table with incremental expansion, segmented
//! LRU (HOT/WARM/COLD) with per-class eviction, lazy expiry, CAS — plus
//! the paper-specific hooks: per-set size collection and **live slab
//! reconfiguration** (migrating every item into a new chunk geometry).

pub mod arena;
pub mod hashtable;
pub mod item;
pub mod lru;
pub mod maintainer;
pub mod migrate;
pub mod optimistic;
pub mod restart;
pub mod sharded;
#[allow(clippy::module_inception)]
pub mod store;

pub use item::{total_item_size, ITEM_HEADER, TAIL_CRLF};
pub use maintainer::{spawn_maintainer, MaintainerConfig};
pub use migrate::MigrationGauges;
pub use restart::{open_or_cold, write_manifest, RestartReport};
pub use sharded::{RestartSnapshot, ShardedStore};
pub use store::{KvStore, MigrationReport, StoreError, StoreStats, Value};
