//! The background maintenance engine — memcached's `lru_maintainer`
//! thread, grown to own every piece of management work the request
//! path used to pay for inline:
//!
//! * **LRU tier rebalance**: the set path only links new items into
//!   HOT ([`ClassLru::insert`] is O(1)); this thread demotes over-cap
//!   HOT/WARM tails into COLD in bounded batches under short per-shard
//!   write-lock leases ([`KvStore::maintain`]).
//! * **Migration pumping**: while an incremental slab migration is
//!   draining (kicked off by `slabs reconfigure`, `slabs optimize`, or
//!   the auto-tuner), the maintainer drives bounded
//!   [`ShardedStore::migration_step_all`] steps so a drain completes
//!   even when the optimizer thread is not running.
//! * **Slack shedding**: after a drain into a less-dense geometry, up
//!   to `MIGRATION_PAGE_SLACK` carved pages can outlive the migration;
//!   the maintainer re-drains them (one page per pass, residents
//!   enumerated in O(chunks/page) through the per-page item index) and
//!   returns the buffers to the OS.
//! * **Deferred read-side effects**: optimistic (lock-free) gets queue
//!   their LRU bumps and fetched-bit sets on per-shard rings
//!   ([`ShardedStore::drain_deferred`]); every pass drains and applies
//!   them under one short write-lock lease per shard, keeping LRU
//!   ordering fresh without the read path ever writing shared state.
//!
//! The thread shares the auto-tuner's clock discipline (a fixed tick,
//! work only when there is work) but is independent of it: servers
//! without the optimizer still get background maintenance.
//!
//! [`ClassLru::insert`]: crate::store::lru::ClassLru::insert
//! [`KvStore::maintain`]: crate::store::store::KvStore::maintain

use super::sharded::ShardedStore;
use crate::util::{failpoint, supervisor};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Default milliseconds between maintenance passes
/// (`memory.maintainer_interval_ms` / `--maintainer-interval-ms`).
pub const DEFAULT_MAINTAINER_INTERVAL_MS: u64 = 100;

/// Default demotion budget per shard per pass
/// (`memory.maintainer_batch` / `--maintainer-batch`) — the write-lock
/// lease is bounded by this many O(1) list moves.
pub const DEFAULT_MAINTAINER_BATCH: usize = 1024;

/// Maintainer thread knobs.
#[derive(Clone, Debug)]
pub struct MaintainerConfig {
    /// Milliseconds between passes when there is no migration to pump.
    pub interval_ms: u64,
    /// Max demotions per shard per pass (lock-lease bound).
    pub batch: usize,
    /// Drive in-flight migrations (`migration_step_all`). Exactly one
    /// thread should pump a drain: when the optimizer's autotune
    /// thread is running it is the designated driver, and this must be
    /// `false` — two phase-shifted pumpers acquire every shard's write
    /// lock near back-to-back and erode the reader "breathe" window
    /// that keeps drains bounded-pause. Default `true` (standalone
    /// stores with no autotune thread).
    pub pump_migration: bool,
    /// Evaluate tenant memory arbitration every this many maintenance
    /// passes (`tenants.arbitrate_every` / `--tenant-arbitrate-every`;
    /// 0 disables). Enforcement reclaims bounded cold-tail batches
    /// through the same short write leases as demotion — never a
    /// stop-the-world repartition.
    pub arbitrate_every: u64,
}

impl Default for MaintainerConfig {
    fn default() -> Self {
        MaintainerConfig {
            interval_ms: DEFAULT_MAINTAINER_INTERVAL_MS,
            batch: DEFAULT_MAINTAINER_BATCH,
            pump_migration: true,
            arbitrate_every: crate::tenant::DEFAULT_ARBITRATE_EVERY,
        }
    }
}

/// Spawn the background maintainer. Stops (promptly) when `shutdown`
/// flips; join the handle to be sure it exited.
///
/// The pass loop runs under [`supervisor::supervise`]: a panicking pass
/// (lock-poisoning recovery gone wrong, an injected
/// `maintainer.pass.panic`) is logged, counted in `thread_restarts`,
/// and retried after a capped backoff — and because migration state
/// lives wholly inside the shards, a panic mid-pump leaves the drain
/// resumable and the very next pass picks it back up. The
/// `maintainer.pass.pause` sync point lets tests hold the maintainer
/// quiescent between passes without sleeps.
pub fn spawn_maintainer(
    store: Arc<ShardedStore>,
    cfg: MaintainerConfig,
    shutdown: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("slabforge-maintainer".into())
        .spawn(move || {
            let interval = Duration::from_millis(cfg.interval_ms.max(1));
            let mut passes: u64 = 0;
            supervisor::supervise("maintainer", &shutdown, || {
                failpoint::fired("maintainer.pass.pause");
                failpoint::maybe_panic("maintainer.pass.panic");
                // apply deferred read-side bumps (optimistic-get LRU
                // effects) even while a migration monopolizes the pass
                store.drain_deferred();
                if cfg.pump_migration && store.migration_active() {
                    // pump the drain; breathe between rounds so std's
                    // unfair RwLock cannot starve readers
                    store.migration_step_all();
                    std::thread::sleep(Duration::from_millis(1));
                    return;
                }
                store.maintain_all(cfg.batch);
                passes = passes.wrapping_add(1);
                if cfg.arbitrate_every > 0 && passes % cfg.arbitrate_every == 0 {
                    let reg = store.tenants();
                    let mask = reg.arbitration_mask();
                    if mask != 0 {
                        store.reclaim_tenants(mask, reg.reclaim_batch());
                    }
                }
                std::thread::sleep(interval);
            });
        })
        .expect("spawn maintainer thread")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slab::policy::ChunkSizePolicy;
    use crate::slab::PAGE_SIZE;
    use crate::store::store::Clock;
    use std::time::Instant;

    fn store() -> Arc<ShardedStore> {
        Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                32 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        )
    }

    #[test]
    fn thread_rebalances_what_the_set_path_left_hot() {
        let s = store();
        for i in 0..2000u32 {
            s.set(format!("k{i:05}").as_bytes(), b"v", 0, 0).unwrap();
        }
        assert!(!s.lru_balanced(), "sets must not rebalance inline");
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_maintainer(
            s.clone(),
            MaintainerConfig {
                interval_ms: 1,
                batch: 256,
                ..MaintainerConfig::default()
            },
            stop.clone(),
        );
        let deadline = Instant::now() + Duration::from_secs(10);
        while !s.lru_balanced() {
            assert!(Instant::now() < deadline, "maintainer never converged");
            std::thread::sleep(Duration::from_millis(5));
        }
        let st = s.stats();
        assert!(st.maintainer_runs > 0);
        assert!(st.maintainer_demoted > 0, "demotions moved off-thread");
        // traffic keeps serving while the maintainer runs
        assert_eq!(s.get(b"k00000").unwrap().value, b"v");
        stop.store(true, Ordering::SeqCst);
        h.join().unwrap();
    }

    #[test]
    fn thread_pumps_migration_to_completion() {
        let s = store();
        for i in 0..3000u32 {
            s.set(format!("k{i:05}").as_bytes(), &vec![b'x'; 455], 0, 0)
                .unwrap();
        }
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_maintainer(s.clone(), MaintainerConfig::default(), stop.clone());
        s.begin_reconfigure(ChunkSizePolicy::Explicit(vec![518]))
            .unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while s.migration_active() {
            assert!(Instant::now() < deadline, "maintainer never drained");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(s.migration_gauges().moved, 3000);
        assert_eq!(s.get(b"k00000").unwrap().value.len(), 455);
        stop.store(true, Ordering::SeqCst);
        h.join().unwrap();
    }

    #[test]
    fn thread_enforces_tenant_quota_incrementally() {
        use crate::store::store::MetaSetOpts;
        let s = store();
        let reg = s.tenants().clone();
        reg.define("hog", b"a:", Some(1)).unwrap();
        let opts = MetaSetOpts {
            tenant: 1,
            ..MetaSetOpts::set(0, 0)
        };
        for i in 0..3000u32 {
            s.meta_set(format!("a:{i:05}").as_bytes(), &vec![b'x'; 1000], &opts)
                .unwrap();
        }
        let over = reg.stats_snapshot()[1].used_pages;
        assert!(over > 1, "setup must exceed the 1-page quota (used={over})");
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_maintainer(
            s.clone(),
            MaintainerConfig {
                interval_ms: 1,
                arbitrate_every: 2,
                ..MaintainerConfig::default()
            },
            stop.clone(),
        );
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let used = reg.stats_snapshot()[1].used_pages;
            if used <= 1 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "arbitration never reclaimed (used_pages={used})"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(
            reg.stats_snapshot()[1].quota_evictions > 0,
            "reclaim must be counted as quota evictions"
        );
        stop.store(true, Ordering::SeqCst);
        h.join().unwrap();
    }

    #[test]
    fn shutdown_joins_promptly() {
        let s = store();
        let stop = Arc::new(AtomicBool::new(false));
        let h = spawn_maintainer(s, MaintainerConfig::default(), stop.clone());
        std::thread::sleep(Duration::from_millis(50));
        stop.store(true, Ordering::SeqCst);
        h.join().unwrap();
    }
}
