//! Chained hash table with **incremental expansion** — memcached's
//! `assoc.c` scheme: when the load factor crosses 1.5 the bucket array
//! doubles, and each subsequent operation migrates a few buckets from
//! the old array, so no single request pays the full rehash.
//!
//! The table stores `u32` arena ids and chains through
//! `ItemMeta::hnext`; key equality is delegated to a caller-provided
//! closure because key bytes live in slab chunks, not in the arena.
//!
//! ## Optimistic-reader support
//!
//! The bucket-array geometry is published through a [`TablePub`] so the
//! lock-free read path can walk chains without the shard lock:
//!
//! * Superseded bucket arrays (and superseded [`TableView`] boxes) are
//!   parked in a graveyard instead of freed, so a stale snapshot is
//!   always dereferenceable; there are at most O(log buckets) of them.
//! * The bucket count never drops below [`MIN_BUCKETS`] = the seqlock
//!   stripe count. Because the bucket index is `hash & mask` with
//!   `mask >= STRIPES - 1`, **every item chained in one bucket shares
//!   one seqlock stripe** — a chain relink (which rewrites a
//!   *neighbour* item's `hnext`, or re-heads the bucket) is covered by
//!   the same stripe any reader of that chain validates against.
//! * Expansion relinks (which move whole old buckets while holding no
//!   per-item context) bump the stripe of the bucket being relinked via
//!   the table's own [`SeqStripes`] handle — shared with the owning
//!   `KvStore` so readers see one coherent counter space.

use super::arena::{Arena, NIL};
use super::optimistic::{SeqStripes, TablePub, TableView, STRIPES};
use std::sync::Arc;

/// Buckets double when `items > buckets * LOAD_NUM / LOAD_DEN`.
const LOAD_NUM: usize = 3;
const LOAD_DEN: usize = 2;

/// Old-table buckets migrated per operation during expansion.
const MIGRATE_PER_OP: usize = 2;

/// Bucket-count floor: one stripe must never cover less than one
/// bucket, or a chain could span stripes and escape its readers'
/// validation (see module docs).
pub const MIN_BUCKETS: usize = STRIPES;

pub struct HashTable {
    /// Current (possibly expanded) bucket array.
    primary: Vec<u32>,
    /// Old bucket array while migrating, empty otherwise.
    old: Vec<u32>,
    /// Next old bucket to migrate.
    migrate_pos: usize,
    items: usize,
    mask: u64,
    old_mask: u64,
    /// Stripe counters shared with the owning store (private stripes
    /// when constructed standalone, e.g. in unit tests).
    seq: Arc<SeqStripes>,
    /// Geometry published to lock-free readers.
    publish: Arc<TablePub>,
    /// Every view ever published (the last one is current); kept alive
    /// for readers holding stale snapshots.
    views: Vec<Box<TableView>>,
    /// Retired bucket arrays, kept mapped for stale-view readers.
    graveyard: Vec<Vec<u32>>,
}

impl HashTable {
    pub fn new() -> Self {
        Self::with_buckets(1024)
    }

    pub fn with_buckets(n: usize) -> Self {
        Self::with_buckets_and_seq(n, Arc::new(SeqStripes::new()))
    }

    /// Construct with the owning store's stripe counters (the handle
    /// expansion relinks bump).
    pub fn with_buckets_and_seq(n: usize, seq: Arc<SeqStripes>) -> Self {
        let n = n.next_power_of_two().max(MIN_BUCKETS);
        let mut t = HashTable {
            primary: vec![NIL; n],
            old: Vec::new(),
            migrate_pos: 0,
            items: 0,
            mask: (n - 1) as u64,
            old_mask: 0,
            seq,
            publish: Arc::new(TablePub::new()),
            views: Vec::new(),
            graveyard: Vec::new(),
        };
        t.republish();
        t
    }

    /// Handle for the optimistic read path.
    pub fn publish_handle(&self) -> Arc<TablePub> {
        self.publish.clone()
    }

    /// Publish the current geometry; the superseded view box stays in
    /// `views` for readers that already snapshotted it.
    fn republish(&mut self) {
        let view = Box::new(TableView {
            prim_base: self.primary.as_ptr() as usize,
            prim_mask: self.mask,
            old_base: if self.old.is_empty() {
                0
            } else {
                self.old.as_ptr() as usize
            },
            old_mask: self.old_mask,
        });
        self.views.push(view);
        let raw = &**self.views.last().unwrap() as *const TableView as *mut TableView;
        self.publish.publish(raw);
    }

    pub fn len(&self) -> usize {
        self.items
    }

    pub fn is_empty(&self) -> bool {
        self.items == 0
    }

    pub fn buckets(&self) -> usize {
        self.primary.len()
    }

    pub fn is_expanding(&self) -> bool {
        !self.old.is_empty()
    }

    #[inline]
    fn bucket_for(&self, hash: u64) -> BucketRef {
        if self.is_expanding() {
            let ob = (hash & self.old_mask) as usize;
            if ob >= self.migrate_pos {
                return BucketRef::Old(ob);
            }
        }
        BucketRef::Primary((hash & self.mask) as usize)
    }

    /// Find the id of the item with this hash satisfying `key_eq`.
    pub fn find<F: Fn(u32) -> bool>(&self, hash: u64, arena: &Arena, key_eq: F) -> Option<u32> {
        let head = match self.bucket_for(hash) {
            BucketRef::Primary(b) => self.primary[b],
            BucketRef::Old(b) => self.old[b],
        };
        let mut id = head;
        while id != NIL {
            let m = arena.get(id);
            if m.hash == hash && key_eq(id) {
                return Some(id);
            }
            id = m.hnext;
        }
        None
    }

    /// Insert a (new, unlinked) id. Caller guarantees no duplicate key.
    pub fn insert(&mut self, id: u32, hash: u64, arena: &mut Arena) {
        match self.bucket_for(hash) {
            BucketRef::Primary(b) => {
                arena.get_mut(id).hnext = self.primary[b];
                self.primary[b] = id;
            }
            BucketRef::Old(b) => {
                arena.get_mut(id).hnext = self.old[b];
                self.old[b] = id;
            }
        }
        self.items += 1;
        self.maybe_start_expand();
        self.migrate_step(arena);
    }

    /// Unlink an id (must be present).
    pub fn remove(&mut self, id: u32, hash: u64, arena: &mut Arena) {
        let head_slot = match self.bucket_for(hash) {
            BucketRef::Primary(b) => &mut self.primary[b],
            BucketRef::Old(b) => &mut self.old[b],
        };
        let mut cur = *head_slot;
        if cur == id {
            *head_slot = arena.get(id).hnext;
        } else {
            loop {
                assert!(cur != NIL, "remove of unlinked id {id}");
                let next = arena.get(cur).hnext;
                if next == id {
                    arena.get_mut(cur).hnext = arena.get(id).hnext;
                    break;
                }
                cur = next;
            }
        }
        arena.get_mut(id).hnext = NIL;
        self.items -= 1;
        self.migrate_step(arena);
    }

    fn maybe_start_expand(&mut self) {
        if self.is_expanding() || self.items * LOAD_DEN <= self.primary.len() * LOAD_NUM {
            return;
        }
        let new_size = self.primary.len() * 2;
        let old = std::mem::replace(&mut self.primary, vec![NIL; new_size]);
        self.old_mask = (old.len() - 1) as u64;
        self.old = old;
        self.migrate_pos = 0;
        self.mask = (new_size - 1) as u64;
        // readers snapshotting before this publish walk the old array as
        // "primary" — every item is still linked there, so both views
        // stay coherent until relinks start bumping stripes
        self.republish();
    }

    /// Expansion finished: park the drained old array for stale-view
    /// readers and publish the single-array geometry.
    fn complete_expansion(&mut self) {
        let drained = std::mem::take(&mut self.old);
        self.graveyard.push(drained);
        self.old_mask = 0;
        self.migrate_pos = 0;
        self.republish();
    }

    /// Migrate up to [`MIGRATE_PER_OP`] old buckets into the primary.
    fn migrate_step(&mut self, arena: &mut Arena) {
        if !self.is_expanding() {
            return;
        }
        for _ in 0..MIGRATE_PER_OP {
            if self.migrate_pos >= self.old.len() {
                self.complete_expansion();
                return;
            }
            // one stripe covers the old bucket and every primary bucket
            // its items re-head into (same hash low bits)
            let _g = self.seq.guard_stripe(self.migrate_pos & (STRIPES - 1));
            let mut id = std::mem::replace(&mut self.old[self.migrate_pos], NIL);
            while id != NIL {
                let next = arena.get(id).hnext;
                let b = (arena.get(id).hash & self.mask) as usize;
                arena.get_mut(id).hnext = self.primary[b];
                self.primary[b] = id;
                id = next;
            }
            self.migrate_pos += 1;
        }
        if self.migrate_pos >= self.old.len() {
            self.complete_expansion();
        }
    }

    /// Force-complete any in-flight expansion (used before migration
    /// snapshots and in tests).
    pub fn finish_expansion(&mut self, arena: &mut Arena) {
        while self.is_expanding() {
            self.migrate_step(arena);
        }
    }
}

impl Default for HashTable {
    fn default() -> Self {
        Self::new()
    }
}

enum BucketRef {
    Primary(usize),
    Old(usize),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::arena::ItemMeta;
    use crate::store::item::hash_key;

    fn put(t: &mut HashTable, a: &mut Arena, key: u64) -> u32 {
        let mut m = dummy();
        m.hash = key;
        let id = a.insert(m);
        t.insert(id, key, a);
        id
    }

    fn dummy() -> ItemMeta {
        // ItemMeta::vacant is private; build via Arena round-trip helper
        ItemMeta {
            hash: 0,
            handle: crate::slab::ChunkHandle {
                class: 0,
                loc: crate::slab::class::ChunkLoc { page: 0, chunk: 0 },
            },
            chunk_addr: 0,
            klen: 0,
            vlen: 0,
            flags: 0,
            exptime: 0,
            time: 0,
            cas: 0,
            total: 0,
            hnext: NIL,
            prev: NIL,
            next: NIL,
            pg_prev: NIL,
            pg_next: NIL,
            tier: 0,
            fetched: false,
            stale: false,
            win_sent: false,
            gen: 0,
            live: true,
            tenant: 0,
        }
    }

    #[test]
    fn insert_find_remove() {
        let mut t = HashTable::with_buckets(4);
        let mut a = Arena::new();
        let h = hash_key(b"k1");
        let id = put(&mut t, &mut a, h);
        assert_eq!(t.find(h, &a, |i| i == id), Some(id));
        t.remove(id, h, &mut a);
        assert_eq!(t.find(h, &a, |_| true), None);
        assert_eq!(t.len(), 0);
    }

    #[test]
    fn collisions_chain() {
        let mut t = HashTable::with_buckets(2);
        let mut a = Arena::new();
        // same bucket (hash & 1), different hashes
        let id1 = put(&mut t, &mut a, 0b100);
        let id2 = put(&mut t, &mut a, 0b010);
        let _ = id2;
        assert_eq!(t.find(0b100, &a, |i| i == id1), Some(id1));
    }

    #[test]
    fn expansion_preserves_items() {
        let mut t = HashTable::with_buckets(4);
        let mut a = Arena::new();
        let ids: Vec<(u32, u64)> = (0..500u64)
            .map(|k| {
                let h = hash_key(&k.to_le_bytes());
                (put(&mut t, &mut a, h), h)
            })
            .collect();
        assert!(t.buckets() > 4, "table should have expanded");
        for (id, h) in &ids {
            assert_eq!(t.find(*h, &a, |i| i == *id), Some(*id), "lost id {id}");
        }
        assert_eq!(t.len(), 500);
    }

    #[test]
    fn removals_during_expansion() {
        let mut t = HashTable::with_buckets(4);
        let mut a = Arena::new();
        let ids: Vec<(u32, u64)> = (0..100u64)
            .map(|k| {
                let h = hash_key(&k.to_le_bytes());
                (put(&mut t, &mut a, h), h)
            })
            .collect();
        for (id, h) in &ids {
            t.remove(*id, *h, &mut a);
            a.remove(*id);
        }
        assert_eq!(t.len(), 0);
        assert!(!t.is_expanding() || t.len() == 0);
    }

    #[test]
    fn finish_expansion_settles() {
        let mut t = HashTable::with_buckets(2);
        let mut a = Arena::new();
        for k in 0..64u64 {
            put(&mut t, &mut a, hash_key(&k.to_le_bytes()));
        }
        t.finish_expansion(&mut a);
        assert!(!t.is_expanding());
        for k in 0..64u64 {
            let h = hash_key(&k.to_le_bytes());
            assert!(t.find(h, &a, |i| a.get(i).hash == h).is_some());
        }
    }

    #[test]
    fn bucket_floor_is_stripe_count() {
        // the chain-per-stripe invariant the optimistic reader relies on
        let t = HashTable::with_buckets(2);
        assert_eq!(t.buckets(), MIN_BUCKETS);
        assert!(HashTable::new().buckets() >= MIN_BUCKETS);
    }

    #[test]
    fn expansion_republishes_and_parks_arrays() {
        let mut t = HashTable::with_buckets(64);
        let mut a = Arena::new();
        let p = t.publish_handle();
        let v0 = p.snapshot().unwrap();
        assert_eq!(v0.prim_mask, 63);
        assert_eq!(v0.old_base, 0, "no expansion yet");
        for k in 0..200u64 {
            put(&mut t, &mut a, hash_key(&k.to_le_bytes()));
        }
        t.finish_expansion(&mut a);
        let v1 = p.snapshot().unwrap();
        assert!(v1.prim_mask > 63, "expanded geometry published");
        assert_eq!(v1.old_base, 0, "expansion complete in final view");
        assert!(
            !t.graveyard.is_empty(),
            "drained arrays parked for stale-view readers"
        );
        // stale view v0's array is one of the parked ones — still mapped
        assert!(t
            .graveyard
            .iter()
            .any(|g| g.as_ptr() as usize == v0.prim_base));
    }

    #[test]
    fn expansion_relinks_bump_their_stripes() {
        let seq = Arc::new(SeqStripes::new());
        let mut t = HashTable::with_buckets_and_seq(64, seq.clone());
        let mut a = Arena::new();
        let before: Vec<u64> = (0..STRIPES).map(|s| seq.begin_read(s)).collect();
        for k in 0..200u64 {
            put(&mut t, &mut a, hash_key(&k.to_le_bytes()));
        }
        t.finish_expansion(&mut a);
        let moved = (0..STRIPES)
            .filter(|&s| seq.begin_read(s) != before[s])
            .count();
        assert!(moved > 0, "relinked buckets must bump stripes");
        for s in 0..STRIPES {
            assert_eq!(seq.begin_read(s) & 1, 0, "all windows closed");
        }
    }

    #[test]
    fn duplicate_hash_distinct_ids() {
        let mut t = HashTable::with_buckets(8);
        let mut a = Arena::new();
        let id1 = put(&mut t, &mut a, 7);
        let id2 = put(&mut t, &mut a, 7);
        // key_eq disambiguates same-hash items
        assert_eq!(t.find(7, &a, |i| i == id1), Some(id1));
        assert_eq!(t.find(7, &a, |i| i == id2), Some(id2));
    }
}
