//! In-house property-testing helper (proptest is not vendored in this
//! offline image — DESIGN.md §3): seeded random case generation with
//! failure reporting that prints the reproducing seed.

use crate::util::rng::Pcg64;

/// Run `cases` random property checks. On panic, re-raises with the
/// failing case index and seed so the case is reproducible with
/// `check_with_seed`.
pub fn check<F: Fn(&mut Pcg64) + std::panic::RefUnwindSafe>(name: &str, cases: u64, f: F) {
    let base_seed = 0x5eed_0000u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg64::new(seed);
            f(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with testutil::check_with_seed(\"{name}\", {seed:#x}, ...)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_with_seed<F: Fn(&mut Pcg64)>(_name: &str, seed: u64, f: F) {
    let mut rng = Pcg64::new(seed);
    f(&mut rng);
}

/// Generator helpers for common test inputs.
pub mod gen {
    use crate::util::rng::Pcg64;

    /// Ascending distinct sizes in `[lo, hi]`.
    pub fn ascending_sizes(rng: &mut Pcg64, n: usize, lo: u32, hi: u32) -> Vec<u32> {
        assert!(hi - lo >= n as u32);
        let mut set = std::collections::BTreeSet::new();
        while set.len() < n {
            set.insert(lo + rng.gen_range((hi - lo + 1) as u64) as u32);
        }
        set.into_iter().collect()
    }

    /// (size, count) pairs, ascending sizes, counts in `[1, cmax]`.
    pub fn histogram_pairs(
        rng: &mut Pcg64,
        n: usize,
        size_hi: u32,
        cmax: u64,
    ) -> Vec<(u32, u64)> {
        ascending_sizes(rng, n, 1, size_hi)
            .into_iter()
            .map(|s| (s, 1 + rng.gen_range(cmax)))
            .collect()
    }

    /// Random printable key of length `1..=max_len`.
    pub fn key(rng: &mut Pcg64, max_len: usize) -> Vec<u8> {
        let len = 1 + rng.gen_range(max_len as u64) as usize;
        (0..len)
            .map(|_| b'a' + rng.gen_range(26) as u8)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::sync::atomic::AtomicU64::new(0);
        check("count", 10, |_| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 10);
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("fails", 5, |rng| {
                assert!(rng.gen_range(10) < 100, "always true");
                assert!(false, "forced failure");
            });
        });
        let msg = format!("{:?}", result.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("forced failure"), "{msg}");
    }

    #[test]
    fn generators_produce_valid_shapes() {
        let mut rng = Pcg64::new(1);
        let sizes = gen::ascending_sizes(&mut rng, 10, 5, 1000);
        assert_eq!(sizes.len(), 10);
        assert!(sizes.windows(2).all(|w| w[0] < w[1]));
        let pairs = gen::histogram_pairs(&mut rng, 8, 500, 100);
        assert!(pairs.iter().all(|&(s, c)| s >= 1 && s <= 500 && c >= 1));
        let k = gen::key(&mut rng, 20);
        assert!(!k.is_empty() && k.len() <= 20);
    }
}
