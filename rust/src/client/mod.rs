//! Blocking memcached text-protocol client — used by the examples, the
//! end-to-end benches, and the integration tests to drive a live
//! `slabforge` (or real memcached) server.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// A fetched value with metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientValue {
    pub value: Vec<u8>,
    pub flags: u32,
    pub cas: Option<u64>,
}

/// One parsed meta-protocol response: the return code (`HD`, `VA`,
/// `EN`, `NS`, `EX`, `NF`, `MN`), the echoed flag tokens, and the data
/// block when the code is `VA`.
#[derive(Debug, Clone, PartialEq)]
pub struct MetaResponse {
    pub code: String,
    pub flags: Vec<String>,
    pub data: Option<Vec<u8>>,
}

impl MetaResponse {
    /// The token of echo flag `c` (e.g. `flag('c')` on `HD c42` →
    /// `Some("42")`).
    pub fn flag(&self, c: char) -> Option<&str> {
        self.flags
            .iter()
            .find(|f| f.starts_with(c))
            .map(|f| &f[c.len_utf8()..])
    }
}

/// Client-side failures.
#[derive(Debug)]
pub enum ClientError {
    Io(std::io::Error),
    /// Server replied with ERROR / CLIENT_ERROR / SERVER_ERROR.
    Server(String),
    /// Response did not match the protocol grammar.
    Protocol(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io: {e}"),
            ClientError::Server(m) => write!(f, "server: {m}"),
            ClientError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> Self {
        ClientError::Io(e)
    }
}

type Result<T> = std::result::Result<T, ClientError>;

/// Blocking connection to one server.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(30)))?;
        let writer = stream.try_clone()?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer,
        })
    }

    fn read_line(&mut self) -> Result<String> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        if line.is_empty() {
            return Err(ClientError::Protocol("connection closed".into()));
        }
        Ok(line.trim_end().to_string())
    }

    fn check_error(line: &str) -> Result<()> {
        if line == "ERROR"
            || line.starts_with("CLIENT_ERROR")
            || line.starts_with("SERVER_ERROR")
        {
            return Err(ClientError::Server(line.to_string()));
        }
        Ok(())
    }

    fn simple_command(&mut self, cmd: &str) -> Result<String> {
        self.writer.write_all(cmd.as_bytes())?;
        let line = self.read_line()?;
        Self::check_error(&line)?;
        Ok(line)
    }

    // -------------------------------------------------------------- storage

    pub fn set(&mut self, key: &str, value: &[u8], flags: u32, exptime: u32) -> Result<()> {
        let resp = self.store_command("set", key, value, flags, exptime, None)?;
        if resp == "STORED" {
            Ok(())
        } else {
            Err(ClientError::Protocol(format!("set -> {resp}")))
        }
    }

    /// Fire-and-forget set (`noreply`): no response round-trip.
    pub fn set_noreply(&mut self, key: &str, value: &[u8], flags: u32, exptime: u32) -> Result<()> {
        let header = format!("set {key} {flags} {exptime} {} noreply\r\n", value.len());
        self.writer.write_all(header.as_bytes())?;
        self.writer.write_all(value)?;
        self.writer.write_all(b"\r\n")?;
        Ok(())
    }

    pub fn add(&mut self, key: &str, value: &[u8], flags: u32, exptime: u32) -> Result<bool> {
        Ok(self.store_command("add", key, value, flags, exptime, None)? == "STORED")
    }

    pub fn replace(&mut self, key: &str, value: &[u8], flags: u32, exptime: u32) -> Result<bool> {
        Ok(self.store_command("replace", key, value, flags, exptime, None)? == "STORED")
    }

    pub fn append(&mut self, key: &str, value: &[u8]) -> Result<bool> {
        Ok(self.store_command("append", key, value, 0, 0, None)? == "STORED")
    }

    pub fn prepend(&mut self, key: &str, value: &[u8]) -> Result<bool> {
        Ok(self.store_command("prepend", key, value, 0, 0, None)? == "STORED")
    }

    /// Returns the response word: STORED / EXISTS / NOT_FOUND.
    pub fn cas(
        &mut self,
        key: &str,
        value: &[u8],
        flags: u32,
        exptime: u32,
        cas: u64,
    ) -> Result<String> {
        self.store_command("cas", key, value, flags, exptime, Some(cas))
    }

    fn store_command(
        &mut self,
        verb: &str,
        key: &str,
        value: &[u8],
        flags: u32,
        exptime: u32,
        cas: Option<u64>,
    ) -> Result<String> {
        let header = match cas {
            Some(c) => format!("{verb} {key} {flags} {exptime} {} {c}\r\n", value.len()),
            None => format!("{verb} {key} {flags} {exptime} {}\r\n", value.len()),
        };
        self.writer.write_all(header.as_bytes())?;
        self.writer.write_all(value)?;
        self.writer.write_all(b"\r\n")?;
        let line = self.read_line()?;
        Self::check_error(&line)?;
        Ok(line)
    }

    // ------------------------------------------------------------ retrieval

    pub fn get(&mut self, key: &str) -> Result<Option<ClientValue>> {
        let mut map = self.get_multi(&[key], false)?;
        Ok(map.remove(key))
    }

    pub fn gets(&mut self, key: &str) -> Result<Option<ClientValue>> {
        let mut map = self.get_multi(&[key], true)?;
        Ok(map.remove(key))
    }

    pub fn get_multi(
        &mut self,
        keys: &[&str],
        with_cas: bool,
    ) -> Result<BTreeMap<String, ClientValue>> {
        let verb = if with_cas { "gets" } else { "get" };
        let cmd = format!("{verb} {}\r\n", keys.join(" "));
        self.writer.write_all(cmd.as_bytes())?;
        self.read_values()
    }

    /// Read `VALUE ...` lines until `END` (shared by `get`/`gets`/
    /// `gat`/`gats`).
    fn read_values(&mut self) -> Result<BTreeMap<String, ClientValue>> {
        let mut found = BTreeMap::new();
        loop {
            let line = self.read_line()?;
            Self::check_error(&line)?;
            if line == "END" {
                return Ok(found);
            }
            let mut parts = line.split_whitespace();
            if parts.next() != Some("VALUE") {
                return Err(ClientError::Protocol(format!("unexpected line '{line}'")));
            }
            let key = parts
                .next()
                .ok_or_else(|| ClientError::Protocol("missing key".into()))?
                .to_string();
            let flags: u32 = parse_field(parts.next(), "flags")?;
            let nbytes: usize = parse_field(parts.next(), "bytes")?;
            let cas = match parts.next() {
                Some(tok) => Some(
                    tok.parse::<u64>()
                        .map_err(|_| ClientError::Protocol("bad cas".into()))?,
                ),
                None => None,
            };
            let mut value = vec![0u8; nbytes + 2];
            self.reader.read_exact(&mut value)?;
            value.truncate(nbytes);
            found.insert(key, ClientValue { value, flags, cas });
        }
    }

    // ---------------------------------------------------------------- meta

    /// Read one meta response (line + data block when `VA`).
    fn read_meta(&mut self) -> Result<MetaResponse> {
        let line = self.read_line()?;
        Self::check_error(&line)?;
        let mut parts = line.split_whitespace();
        let code = parts
            .next()
            .ok_or_else(|| ClientError::Protocol("empty meta response".into()))?
            .to_string();
        if code == "VA" {
            let size: usize = parse_field(parts.next(), "size")?;
            let flags: Vec<String> = parts.map(str::to_string).collect();
            let mut data = vec![0u8; size + 2];
            self.reader.read_exact(&mut data)?;
            data.truncate(size);
            Ok(MetaResponse {
                code,
                flags,
                data: Some(data),
            })
        } else {
            Ok(MetaResponse {
                code,
                flags: parts.map(str::to_string).collect(),
                data: None,
            })
        }
    }

    fn meta_line(verb: &str, key: &str, flags: &[&str]) -> String {
        let mut line = format!("{verb} {key}");
        for f in flags {
            line.push(' ');
            line.push_str(f);
        }
        line.push_str("\r\n");
        line
    }

    /// `mg <key> <flags>*` — meta get.
    pub fn mg(&mut self, key: &str, flags: &[&str]) -> Result<MetaResponse> {
        let line = Self::meta_line("mg", key, flags);
        self.writer.write_all(line.as_bytes())?;
        self.read_meta()
    }

    /// `ms <key> <datalen> <flags>*` + data block — meta set.
    pub fn ms(&mut self, key: &str, value: &[u8], flags: &[&str]) -> Result<MetaResponse> {
        let mut line = format!("ms {key} {}", value.len());
        for f in flags {
            line.push(' ');
            line.push_str(f);
        }
        line.push_str("\r\n");
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(value)?;
        self.writer.write_all(b"\r\n")?;
        self.read_meta()
    }

    /// `md <key> <flags>*` — meta delete.
    pub fn md(&mut self, key: &str, flags: &[&str]) -> Result<MetaResponse> {
        let line = Self::meta_line("md", key, flags);
        self.writer.write_all(line.as_bytes())?;
        self.read_meta()
    }

    /// `ma <key> <flags>*` — meta arithmetic.
    pub fn ma(&mut self, key: &str, flags: &[&str]) -> Result<MetaResponse> {
        let line = Self::meta_line("ma", key, flags);
        self.writer.write_all(line.as_bytes())?;
        self.read_meta()
    }

    /// `mn` — meta no-op / quiet-pipeline barrier. Errors if the next
    /// response line is not `MN` (i.e. an unexpected response was
    /// queued ahead of the barrier).
    pub fn mn(&mut self) -> Result<()> {
        self.writer.write_all(b"mn\r\n")?;
        let r = self.read_meta()?;
        if r.code == "MN" {
            Ok(())
        } else {
            Err(ClientError::Protocol(format!("mn -> {}", r.code)))
        }
    }

    // --------------------------------------------------------------- admin

    /// `gat`/`gats`: get-and-touch every key to `exptime`.
    pub fn gat(
        &mut self,
        exptime: u32,
        keys: &[&str],
        with_cas: bool,
    ) -> Result<BTreeMap<String, ClientValue>> {
        let verb = if with_cas { "gats" } else { "gat" };
        let cmd = format!("{verb} {exptime} {}\r\n", keys.join(" "));
        self.writer.write_all(cmd.as_bytes())?;
        self.read_values()
    }

    /// `stats reset` — zero the resettable counters.
    pub fn stats_reset(&mut self) -> Result<()> {
        let line = self.simple_command("stats reset\r\n")?;
        if line == "RESET" {
            Ok(())
        } else {
            Err(ClientError::Protocol(format!("stats reset -> {line}")))
        }
    }

    pub fn delete(&mut self, key: &str) -> Result<bool> {
        Ok(self.simple_command(&format!("delete {key}\r\n"))? == "DELETED")
    }

    pub fn incr(&mut self, key: &str, delta: u64) -> Result<Option<u64>> {
        self.incr_decr("incr", key, delta)
    }

    pub fn decr(&mut self, key: &str, delta: u64) -> Result<Option<u64>> {
        self.incr_decr("decr", key, delta)
    }

    fn incr_decr(&mut self, verb: &str, key: &str, delta: u64) -> Result<Option<u64>> {
        let line = self.simple_command(&format!("{verb} {key} {delta}\r\n"))?;
        if line == "NOT_FOUND" {
            return Ok(None);
        }
        line.parse::<u64>()
            .map(Some)
            .map_err(|_| ClientError::Protocol(format!("{verb} -> {line}")))
    }

    pub fn touch(&mut self, key: &str, exptime: u32) -> Result<bool> {
        Ok(self.simple_command(&format!("touch {key} {exptime}\r\n"))? == "TOUCHED")
    }

    pub fn flush_all(&mut self) -> Result<()> {
        let line = self.simple_command("flush_all\r\n")?;
        if line == "OK" {
            Ok(())
        } else {
            Err(ClientError::Protocol(format!("flush_all -> {line}")))
        }
    }

    pub fn version(&mut self) -> Result<String> {
        let line = self.simple_command("version\r\n")?;
        Ok(line.strip_prefix("VERSION ").unwrap_or(&line).to_string())
    }

    /// `stats [arg]` as a name → value map.
    pub fn stats(&mut self, arg: Option<&str>) -> Result<BTreeMap<String, String>> {
        let cmd = match arg {
            Some(a) => format!("stats {a}\r\n"),
            None => "stats\r\n".to_string(),
        };
        self.writer.write_all(cmd.as_bytes())?;
        let mut map = BTreeMap::new();
        loop {
            let line = self.read_line()?;
            Self::check_error(&line)?;
            if line == "END" {
                return Ok(map);
            }
            if let Some(rest) = line.strip_prefix("STAT ") {
                if let Some((k, v)) = rest.split_once(' ') {
                    map.insert(k.to_string(), v.to_string());
                }
            }
        }
    }

    /// Extension: live-apply a learned chunk-size configuration.
    pub fn slabs_reconfigure(&mut self, sizes: &[usize]) -> Result<String> {
        let list = sizes
            .iter()
            .map(usize::to_string)
            .collect::<Vec<_>>()
            .join(",");
        self.simple_command(&format!("slabs reconfigure {list}\r\n"))
    }

    /// Extension: trigger the optimizer now; returns its status line.
    pub fn slabs_optimize(&mut self) -> Result<String> {
        self.simple_command("slabs optimize\r\n")
    }

    /// Extension: `tenants [list|define ...|token ...|quota ...]` —
    /// multi-tenant registry control. Returns every response line:
    /// `list` yields `TENANT ...` rows plus the closing `END`, the
    /// mutating verbs yield a single `OK <id>` line.
    pub fn tenants(&mut self, args: &str) -> Result<Vec<String>> {
        let cmd = if args.is_empty() {
            "tenants\r\n".to_string()
        } else {
            format!("tenants {args}\r\n")
        };
        self.writer.write_all(cmd.as_bytes())?;
        let mut lines = Vec::new();
        loop {
            let line = self.read_line()?;
            Self::check_error(&line)?;
            let done = line == "END" || !line.starts_with("TENANT ");
            lines.push(line);
            if done {
                return Ok(lines);
            }
        }
    }

    pub fn quit(mut self) {
        let _ = self.writer.write_all(b"quit\r\n");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::Server;
    use crate::slab::policy::ChunkSizePolicy;
    use crate::slab::PAGE_SIZE;
    use crate::store::sharded::ShardedStore;
    use crate::store::store::Clock;
    use std::sync::Arc;

    fn server() -> crate::server::ServerHandle {
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                16 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        Server::new(store).start("127.0.0.1:0").unwrap()
    }

    #[test]
    fn full_client_flow() {
        let h = server();
        let mut c = Client::connect(h.addr()).unwrap();

        c.set("k", b"hello", 7, 0).unwrap();
        let v = c.get("k").unwrap().unwrap();
        assert_eq!(v.value, b"hello");
        assert_eq!(v.flags, 7);
        assert_eq!(v.cas, None);

        let v = c.gets("k").unwrap().unwrap();
        let cas = v.cas.unwrap();
        assert_eq!(c.cas("k", b"world", 0, 0, cas).unwrap(), "STORED");
        assert_eq!(c.cas("k", b"xxx", 0, 0, cas).unwrap(), "EXISTS");

        assert!(!c.add("k", b"nope", 0, 0).unwrap());
        assert!(c.replace("k", b"replaced", 0, 0).unwrap());
        assert!(c.append("k", b"-tail").unwrap());
        assert_eq!(c.get("k").unwrap().unwrap().value, b"replaced-tail");

        c.set("n", b"41", 0, 0).unwrap();
        assert_eq!(c.incr("n", 1).unwrap(), Some(42));
        assert_eq!(c.decr("n", 2).unwrap(), Some(40));
        assert_eq!(c.incr("absent", 1).unwrap(), None);

        assert!(c.touch("k", 300).unwrap());
        assert!(c.delete("k").unwrap());
        assert!(!c.delete("k").unwrap());
        assert!(c.get("k").unwrap().is_none());

        let stats = c.stats(None).unwrap();
        assert!(stats.contains_key("curr_items"));
        assert!(c.version().unwrap().contains('.'));

        c.flush_all().unwrap();
        assert!(c.get("n").unwrap().is_none());
        c.quit();
        h.shutdown();
    }

    #[test]
    fn multi_get() {
        let h = server();
        let mut c = Client::connect(h.addr()).unwrap();
        c.set("a", b"1", 0, 0).unwrap();
        c.set("b", b"22", 0, 0).unwrap();
        let m = c.get_multi(&["a", "b", "missing"], false).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m["a"].value, b"1");
        assert_eq!(m["b"].value, b"22");
        h.shutdown();
    }

    #[test]
    fn noreply_pipeline() {
        let h = server();
        let mut c = Client::connect(h.addr()).unwrap();
        for i in 0..100 {
            c.set_noreply(&format!("k{i}"), b"v", 0, 0).unwrap();
        }
        // a replied command flushes the pipeline
        assert_eq!(c.get("k99").unwrap().unwrap().value, b"v");
        let stats = c.stats(None).unwrap();
        assert_eq!(stats["curr_items"], "100");
        h.shutdown();
    }

    #[test]
    fn meta_commands_roundtrip() {
        let h = server();
        let mut c = Client::connect(h.addr()).unwrap();

        let r = c.ms("mk", b"hello", &["F7", "c", "k"]).unwrap();
        assert_eq!(r.code, "HD");
        let cas: u64 = r.flag('c').unwrap().parse().unwrap();
        assert_eq!(r.flag('k'), Some("mk"));

        let r = c.mg("mk", &["v", "f", "c", "t", "k"]).unwrap();
        assert_eq!(r.code, "VA");
        assert_eq!(r.data.as_deref(), Some(b"hello".as_ref()));
        assert_eq!(r.flag('f'), Some("7"));
        assert_eq!(r.flag('t'), Some("-1"));
        assert_eq!(r.flag('c').unwrap().parse::<u64>().unwrap(), cas);

        let r = c.mg("missing", &["v"]).unwrap();
        assert_eq!(r.code, "EN");

        let err = c.ma("mk", &[]).unwrap_err(); // non-numeric value
        assert!(matches!(err, ClientError::Server(_)), "{err}");
        h.shutdown();
    }

    #[test]
    fn meta_quiet_pipeline_with_barrier() {
        let h = server();
        let mut c = Client::connect(h.addr()).unwrap();
        // quiet misses produce nothing; mn is the only response
        c.writer
            .write_all(b"mg gone1 v q\r\nmg gone2 v q\r\n")
            .unwrap();
        c.mn().unwrap();
        h.shutdown();
    }

    #[test]
    fn gat_touches_over_the_wire() {
        let h = server();
        let mut c = Client::connect(h.addr()).unwrap();
        c.set("g1", b"x", 3, 60).unwrap();
        let m = c.gat(300, &["g1", "missing"], false).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m["g1"].value, b"x");
        assert_eq!(m["g1"].flags, 3);
        assert!(m["g1"].cas.is_none());
        let m = c.gat(300, &["g1"], true).unwrap();
        assert!(m["g1"].cas.is_some(), "gats returns cas");
        // TTL observable through the meta t flag
        let r = c.mg("g1", &["t"]).unwrap();
        let ttl: i64 = r.flag('t').unwrap().parse().unwrap();
        assert!((295..=300).contains(&ttl), "{ttl}");
        h.shutdown();
    }

    #[test]
    fn stats_reset_over_the_wire() {
        let h = server();
        let mut c = Client::connect(h.addr()).unwrap();
        c.set("k", b"v", 0, 0).unwrap();
        c.get("k").unwrap();
        let st = c.stats(None).unwrap();
        assert_ne!(st["cmd_get"], "0");
        c.stats_reset().unwrap();
        let st = c.stats(None).unwrap();
        assert_eq!(st["cmd_get"], "0");
        assert_eq!(st["cmd_set"], "0");
        assert_eq!(st["curr_items"], "1", "items survive");
        h.shutdown();
    }

    #[test]
    fn server_error_surfaces() {
        let h = server();
        let mut c = Client::connect(h.addr()).unwrap();
        let err = c.slabs_optimize().unwrap_err();
        assert!(matches!(err, ClientError::Server(_)));
        h.shutdown();
    }
}

fn parse_field<T: std::str::FromStr>(
    tok: Option<&str>,
    what: &str,
) -> std::result::Result<T, ClientError> {
    tok.and_then(|t| t.parse().ok())
        .ok_or_else(|| ClientError::Protocol(format!("bad {what}")))
}
