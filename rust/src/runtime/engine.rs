//! The PJRT engine: compile the HLO-text artifacts once, then execute
//! them with concrete inputs from the optimizer's control loop.
//!
//! Interchange is HLO **text** (`HloModuleProto::from_text_file`): the
//! image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos
//! (64-bit instruction ids), while the text parser reassigns ids — see
//! /opt/xla-example/README.md and `python/compile/aot.py`.

use super::manifest::{Manifest, ManifestError};
use std::fmt;
use std::path::Path;

/// Runtime failures.
#[derive(Debug)]
pub enum RuntimeError {
    Manifest(ManifestError),
    Xla(xla::Error),
    Shape(String),
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::Manifest(e) => write!(f, "{e}"),
            RuntimeError::Xla(e) => write!(f, "xla: {e}"),
            RuntimeError::Shape(m) => write!(f, "shape mismatch: {m}"),
        }
    }
}

impl std::error::Error for RuntimeError {}

impl From<ManifestError> for RuntimeError {
    fn from(e: ManifestError) -> Self {
        RuntimeError::Manifest(e)
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e)
    }
}

type Result<T> = std::result::Result<T, RuntimeError>;

/// Loaded artifacts on the PJRT CPU client.
///
/// NOT `Send`/`Sync` (the xla crate's wrappers hold `Rc`s): use
/// [`super::service::XlaService`] to share across threads.
pub struct XlaEngine {
    man: Manifest,
    waste_eval: xla::PjRtLoadedExecutable,
    hill_step: xla::PjRtLoadedExecutable,
    fit_lognormal: xla::PjRtLoadedExecutable,
}

impl XlaEngine {
    /// Compile all artifacts under `dir` (one-time cost, then reused).
    pub fn load(dir: &Path) -> Result<XlaEngine> {
        let man = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = man.artifact_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        Ok(XlaEngine {
            waste_eval: compile("waste_eval")?,
            hill_step: compile("hill_step")?,
            fit_lognormal: compile("fit_lognormal")?,
            man,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.man
    }

    fn run(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(args)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    fn lit1(data: &[f64]) -> xla::Literal {
        xla::Literal::vec1(data)
    }

    fn lit2(data: &[f64], rows: usize, cols: usize) -> Result<xla::Literal> {
        if data.len() != rows * cols {
            return Err(RuntimeError::Shape(format!(
                "{} elements != {rows}x{cols}",
                data.len()
            )));
        }
        Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
    }

    /// `waste_eval(hist[S], sizes[S], configs[B,K]) -> waste[B]`.
    pub fn waste_eval(&self, hist: &[f64], sizes: &[f64], configs: &[f64]) -> Result<Vec<f64>> {
        let (s, b, k) = (self.man.s_buckets, self.man.b_candidates, self.man.k_classes);
        if hist.len() != s || sizes.len() != s {
            return Err(RuntimeError::Shape(format!(
                "hist/sizes len {} != S={s}",
                hist.len()
            )));
        }
        let out = self.run(
            &self.waste_eval,
            &[Self::lit1(hist), Self::lit1(sizes), Self::lit2(configs, b, k)?],
        )?;
        Ok(out[0].to_vec::<f64>()?)
    }

    /// `hill_step(hist, sizes, config[K], deltas[B,K])
    ///  -> (best_config[K], best_waste, wastes[B])` — one fused
    /// steepest-descent step per PJRT call.
    pub fn hill_step(
        &self,
        hist: &[f64],
        sizes: &[f64],
        config: &[f64],
        deltas: &[f64],
    ) -> Result<(Vec<f64>, f64, Vec<f64>)> {
        let (s, b, k) = (self.man.s_buckets, self.man.b_candidates, self.man.k_classes);
        if hist.len() != s || sizes.len() != s || config.len() != k {
            return Err(RuntimeError::Shape("hill_step input shapes".into()));
        }
        let out = self.run(
            &self.hill_step,
            &[
                Self::lit1(hist),
                Self::lit1(sizes),
                Self::lit1(config),
                Self::lit2(deltas, b, k)?,
            ],
        )?;
        let best_config = out[0].to_vec::<f64>()?;
        let best_waste = out[1].to_vec::<f64>()?[0];
        let wastes = out[2].to_vec::<f64>()?;
        Ok((best_config, best_waste, wastes))
    }

    /// `fit_lognormal(hist, sizes) -> (median, sigma_ln, n)` — the
    /// learned traffic-pattern summary driving retune decisions.
    pub fn fit_lognormal(&self, hist: &[f64], sizes: &[f64]) -> Result<(f64, f64, f64)> {
        let out = self.run(&self.fit_lognormal, &[Self::lit1(hist), Self::lit1(sizes)])?;
        Ok((
            out[0].to_vec::<f64>()?[0],
            out[1].to_vec::<f64>()?[0],
            out[2].to_vec::<f64>()?[0],
        ))
    }
}

// NOTE: engine-level tests live in rust/tests/integration_optimizer.rs
// (they need `make artifacts` to have run and a live PJRT client).
