//! `artifacts/manifest.json`: the contract between `aot.py` and the
//! rust runtime — artifact file names, fixed shapes, and the SENTINEL
//! constant both sides must agree on.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

/// One entry point's shape signature.
#[derive(Clone, Debug, PartialEq)]
pub struct EntryPoint {
    pub file: String,
    /// (name, shape) in call order.
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<(String, Vec<usize>)>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub s_buckets: usize,
    pub b_candidates: usize,
    pub k_classes: usize,
    pub sentinel: f64,
    pub entry_points: BTreeMap<String, EntryPoint>,
}

#[derive(Debug)]
pub enum ManifestError {
    Io(std::io::Error),
    Parse(String),
    /// Manifest disagrees with what this build expects.
    Incompatible(String),
}

impl fmt::Display for ManifestError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ManifestError::Io(e) => write!(f, "manifest io: {e}"),
            ManifestError::Parse(m) => write!(f, "manifest parse: {m}"),
            ManifestError::Incompatible(m) => write!(f, "manifest incompatible: {m}"),
        }
    }
}

impl std::error::Error for ManifestError {}

fn field<'a>(v: &'a Json, path: &str) -> Result<&'a Json, ManifestError> {
    let mut cur = v;
    for part in path.split('.') {
        cur = cur
            .get(part)
            .ok_or_else(|| ManifestError::Parse(format!("missing '{path}'")))?;
    }
    Ok(cur)
}

fn shapes(v: &Json, what: &str) -> Result<Vec<(String, Vec<usize>)>, ManifestError> {
    let arr = v
        .as_array()
        .ok_or_else(|| ManifestError::Parse(format!("{what} not an array")))?;
    arr.iter()
        .map(|item| {
            let name = field(item, "name")?
                .as_str()
                .ok_or_else(|| ManifestError::Parse(format!("{what}: bad name")))?
                .to_string();
            let shape = field(item, "shape")?
                .as_array()
                .ok_or_else(|| ManifestError::Parse(format!("{what}: bad shape")))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| ManifestError::Parse(format!("{what}: bad dim")))
                })
                .collect::<Result<Vec<usize>, _>>()?;
            Ok((name, shape))
        })
        .collect()
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let text =
            std::fs::read_to_string(dir.join("manifest.json")).map_err(ManifestError::Io)?;
        let v = Json::parse(&text).map_err(|e| ManifestError::Parse(e.to_string()))?;

        if field(&v, "format")?.as_str() != Some("hlo-text") {
            return Err(ManifestError::Incompatible("format != hlo-text".into()));
        }
        if field(&v, "dtype")?.as_str() != Some("f64") {
            return Err(ManifestError::Incompatible("dtype != f64".into()));
        }

        let s_buckets = field(&v, "constants.s_buckets")?
            .as_usize()
            .ok_or_else(|| ManifestError::Parse("bad s_buckets".into()))?;
        let b_candidates = field(&v, "constants.b_candidates")?
            .as_usize()
            .ok_or_else(|| ManifestError::Parse("bad b_candidates".into()))?;
        let k_classes = field(&v, "constants.k_classes")?
            .as_usize()
            .ok_or_else(|| ManifestError::Parse("bad k_classes".into()))?;
        let sentinel = field(&v, "constants.sentinel")?
            .as_f64()
            .ok_or_else(|| ManifestError::Parse("bad sentinel".into()))?;

        if sentinel != crate::optimizer::waste::SENTINEL as f64 {
            return Err(ManifestError::Incompatible(format!(
                "sentinel {sentinel} != {}",
                crate::optimizer::waste::SENTINEL
            )));
        }

        let eps = field(&v, "entry_points")?
            .as_object()
            .ok_or_else(|| ManifestError::Parse("entry_points not an object".into()))?;
        let mut entry_points = BTreeMap::new();
        for (name, ep) in eps {
            let file = field(ep, "file")?
                .as_str()
                .ok_or_else(|| ManifestError::Parse("bad file".into()))?
                .to_string();
            entry_points.insert(
                name.clone(),
                EntryPoint {
                    file,
                    inputs: shapes(field(ep, "inputs")?, "inputs")?,
                    outputs: shapes(field(ep, "outputs")?, "outputs")?,
                },
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            s_buckets,
            b_candidates,
            k_classes,
            sentinel,
            entry_points,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntryPoint, ManifestError> {
        self.entry_points
            .get(name)
            .ok_or_else(|| ManifestError::Incompatible(format!("missing entry point '{name}'")))
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf, ManifestError> {
        Ok(self.dir.join(&self.entry(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, text: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("slabforge-man-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    const GOOD: &str = r#"{
        "format": "hlo-text", "dtype": "f64",
        "fingerprint": "abc",
        "constants": {"s_buckets": 16384, "b_candidates": 256,
                      "k_classes": 64, "sentinel": 2097152.0},
        "entry_points": {
            "waste_eval": {"file": "waste_eval.hlo.txt",
                "inputs": [{"name": "hist", "shape": [16384]},
                            {"name": "sizes", "shape": [16384]},
                            {"name": "configs", "shape": [256, 64]}],
                "outputs": [{"name": "waste", "shape": [256]}]}
        }
    }"#;

    #[test]
    fn parses_good_manifest() {
        let d = tmpdir("good");
        write_manifest(&d, GOOD);
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.s_buckets, 16384);
        assert_eq!(m.b_candidates, 256);
        assert_eq!(m.k_classes, 64);
        let ep = m.entry("waste_eval").unwrap();
        assert_eq!(ep.inputs[2].1, vec![256, 64]);
        assert_eq!(
            m.artifact_path("waste_eval").unwrap(),
            d.join("waste_eval.hlo.txt")
        );
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn rejects_wrong_sentinel() {
        let d = tmpdir("sent");
        write_manifest(&d, &GOOD.replace("2097152.0", "123.0"));
        assert!(matches!(
            Manifest::load(&d),
            Err(ManifestError::Incompatible(_))
        ));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn rejects_wrong_format() {
        let d = tmpdir("fmt");
        write_manifest(&d, &GOOD.replace("hlo-text", "proto"));
        assert!(matches!(
            Manifest::load(&d),
            Err(ManifestError::Incompatible(_))
        ));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn missing_file_is_io_error() {
        let d = tmpdir("nofile");
        std::fs::create_dir_all(&d).unwrap();
        assert!(matches!(Manifest::load(&d), Err(ManifestError::Io(_))));
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn missing_entry_reported() {
        let d = tmpdir("noentry");
        write_manifest(&d, GOOD);
        let m = Manifest::load(&d).unwrap();
        assert!(m.entry("hill_step").is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // validates the actual `make artifacts` output when it exists
        let dir = Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert_eq!(m.s_buckets, 16384);
            assert!(m.entry("waste_eval").is_ok());
            assert!(m.entry("hill_step").is_ok());
            assert!(m.entry("fit_lognormal").is_ok());
        }
    }
}
