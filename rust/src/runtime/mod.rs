//! PJRT runtime: load the AOT-compiled XLA artifacts
//! (`artifacts/*.hlo.txt`, produced once by `python/compile/aot.py`)
//! and execute them from the rust hot path. Python never runs here.
//!
//! * [`manifest`] — parse + validate `artifacts/manifest.json`.
//! * [`engine`] — the PJRT CPU client, compiled executables, and typed
//!   entry points (`waste_eval`, `hill_step`, `fit_lognormal`).
//! * [`service`] — a `Send + Sync` handle around the engine: the xla
//!   crate's PJRT wrappers are `!Send` (`Rc` internals), so the engine
//!   lives on a dedicated thread behind an mpsc request channel.
//!   [`service::XlaWasteBackend`] plugs it into the optimizer's
//!   [`WasteBackend`](crate::optimizer::WasteBackend).

pub mod engine;
pub mod manifest;
pub mod service;

pub use engine::XlaEngine;
pub use manifest::Manifest;
pub use service::{XlaService, XlaWasteBackend};
