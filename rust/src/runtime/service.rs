//! Thread-safe facade over [`XlaEngine`]: the xla crate's PJRT wrappers
//! are `!Send` (they hold `Rc`s into the C API), so the engine lives on
//! one dedicated worker thread and callers talk to it over an mpsc
//! request channel. Callers block on a per-request reply channel; the
//! handle is cheap to clone and `Send + Sync`.

use super::engine::XlaEngine;
use super::manifest::Manifest;
use crate::optimizer::engine::WasteBackend;
use crate::optimizer::waste::SENTINEL;
use crate::util::histogram::SizeHistogram;
use std::path::Path;
use std::sync::mpsc;
use std::sync::Arc;

type Reply<T> = mpsc::Sender<Result<T, String>>;

enum Req {
    WasteEval {
        hist: Arc<Vec<f64>>,
        sizes: Arc<Vec<f64>>,
        configs: Vec<f64>,
        reply: Reply<Vec<f64>>,
    },
    HillStep {
        hist: Arc<Vec<f64>>,
        sizes: Arc<Vec<f64>>,
        config: Vec<f64>,
        deltas: Vec<f64>,
        reply: Reply<(Vec<f64>, f64, Vec<f64>)>,
    },
    FitLognormal {
        hist: Arc<Vec<f64>>,
        sizes: Arc<Vec<f64>>,
        reply: Reply<(f64, f64, f64)>,
    },
    Shutdown,
}

/// `Send + Sync` handle to the engine worker thread.
pub struct XlaService {
    tx: mpsc::Sender<Req>,
    manifest: Manifest,
}

impl std::fmt::Debug for XlaService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaService")
            .field("artifacts", &self.manifest.dir)
            .finish_non_exhaustive()
    }
}

impl XlaService {
    /// Spawn the worker and compile the artifacts on it. Returns after
    /// compilation finished (fails fast on stale artifacts).
    pub fn start(artifacts_dir: &Path) -> Result<Arc<XlaService>, String> {
        // parse the manifest on the caller to expose shapes cheaply
        let manifest =
            Manifest::load(artifacts_dir).map_err(|e| format!("manifest: {e}"))?;
        let (tx, rx) = mpsc::channel::<Req>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let dir = artifacts_dir.to_path_buf();
        std::thread::Builder::new()
            .name("slabforge-xla".into())
            .spawn(move || {
                let engine = match XlaEngine::load(&dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::WasteEval {
                            hist,
                            sizes,
                            configs,
                            reply,
                        } => {
                            let r = engine
                                .waste_eval(&hist, &sizes, &configs)
                                .map_err(|e| e.to_string());
                            let _ = reply.send(r);
                        }
                        Req::HillStep {
                            hist,
                            sizes,
                            config,
                            deltas,
                            reply,
                        } => {
                            let r = engine
                                .hill_step(&hist, &sizes, &config, &deltas)
                                .map_err(|e| e.to_string());
                            let _ = reply.send(r);
                        }
                        Req::FitLognormal { hist, sizes, reply } => {
                            let r = engine
                                .fit_lognormal(&hist, &sizes)
                                .map_err(|e| e.to_string());
                            let _ = reply.send(r);
                        }
                        Req::Shutdown => return,
                    }
                }
            })
            .map_err(|e| format!("spawn xla worker: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| "xla worker died during startup".to_string())??;
        Ok(Arc::new(XlaService { tx, manifest }))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn call<T, F: FnOnce(Reply<T>) -> Req>(&self, make: F) -> Result<T, String> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(make(reply_tx))
            .map_err(|_| "xla worker gone".to_string())?;
        reply_rx.recv().map_err(|_| "xla worker gone".to_string())?
    }

    pub fn waste_eval(
        &self,
        hist: Arc<Vec<f64>>,
        sizes: Arc<Vec<f64>>,
        configs: Vec<f64>,
    ) -> Result<Vec<f64>, String> {
        self.call(|reply| Req::WasteEval {
            hist,
            sizes,
            configs,
            reply,
        })
    }

    pub fn hill_step(
        &self,
        hist: Arc<Vec<f64>>,
        sizes: Arc<Vec<f64>>,
        config: Vec<f64>,
        deltas: Vec<f64>,
    ) -> Result<(Vec<f64>, f64, Vec<f64>), String> {
        self.call(|reply| Req::HillStep {
            hist,
            sizes,
            config,
            deltas,
            reply,
        })
    }

    pub fn fit_lognormal(
        &self,
        hist: Arc<Vec<f64>>,
        sizes: Arc<Vec<f64>>,
    ) -> Result<(f64, f64, f64), String> {
        self.call(|reply| Req::FitLognormal { hist, sizes, reply })
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Req::Shutdown);
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
    }
}

/// The optimizer-facing backend: a fixed (bucketized) histogram plus
/// the service; every [`WasteBackend::eval_batch`] call scores up to
/// B=256 candidates in one artifact execution.
///
/// Exactness: bucketization is byte-granular (width 1) whenever the
/// largest observed size ≤ S=16384 — true for every paper workload —
/// making results bit-identical to the rust evaluator (asserted in
/// integration tests). Wider buckets degrade gracefully to a
/// lower-bound estimate with upper-edge representative sizes.
pub struct XlaWasteBackend {
    service: Arc<XlaService>,
    hist: Arc<Vec<f64>>,
    sizes: Arc<Vec<f64>>,
}

impl XlaWasteBackend {
    pub fn new(service: &Arc<XlaService>, hist: &SizeHistogram) -> Self {
        let s = service.manifest.s_buckets;
        let b = hist.bucketize(s, s);
        XlaWasteBackend {
            service: service.clone(),
            hist: Arc::new(b.hist),
            sizes: Arc::new(b.sizes),
        }
    }

    /// The fused L2 `hill_step` over this backend's histogram.
    pub fn fused_hill_step(
        &self,
        config: &[u32],
        deltas: &[f64],
    ) -> Result<(Vec<u32>, u64, Vec<u64>), String> {
        let k = self.service.manifest.k_classes;
        let mut cfg = vec![SENTINEL as f64; k];
        for (dst, &c) in cfg.iter_mut().zip(config.iter()) {
            *dst = c as f64;
        }
        let (best_cfg, best_waste, wastes) =
            self.service
                .hill_step(self.hist.clone(), self.sizes.clone(), cfg, deltas.to_vec())?;
        let best: Vec<u32> = best_cfg
            .iter()
            .filter(|&&c| c < SENTINEL as f64)
            .map(|&c| c as u32)
            .collect();
        Ok((
            best,
            best_waste as u64,
            wastes.into_iter().map(|w| w as u64).collect(),
        ))
    }
}

impl WasteBackend for XlaWasteBackend {
    fn eval_batch(&self, configs: &[Vec<u32>]) -> Vec<u64> {
        let b = self.service.manifest.b_candidates;
        let k = self.service.manifest.k_classes;
        let mut out = Vec::with_capacity(configs.len());
        for chunk in configs.chunks(b) {
            let mut flat = vec![SENTINEL as f64; b * k];
            for (row, cfg) in chunk.iter().enumerate() {
                assert!(cfg.len() <= k, "config with {} classes > K={k}", cfg.len());
                for (dst, &c) in flat[row * k..(row + 1) * k].iter_mut().zip(cfg.iter()) {
                    *dst = c as f64;
                }
            }
            let wastes = self
                .service
                .waste_eval(self.hist.clone(), self.sizes.clone(), flat)
                .expect("artifact execution failed");
            out.extend(wastes[..chunk.len()].iter().map(|&w| w as u64));
        }
        out
    }

    fn preferred_batch(&self) -> usize {
        self.service.manifest.b_candidates
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

// Live-engine tests are in rust/tests/integration_optimizer.rs (they
// require `make artifacts`).
