//! Multi-tenant cache layer: request attribution, per-tenant
//! accounting, and Memshare-style memory arbitration.
//!
//! Real deployments multiplex many applications with divergent size
//! distributions onto one cache; a single global learner's classes are
//! a compromise that serves no tenant well (PAPERS.md: *Memshare*,
//! arxiv 1610.08129). This module supplies the three primitives the
//! rest of the system composes:
//!
//! * **Attribution** — every request maps to a tenant id via key-prefix
//!   rules (longest match wins) and/or an exact meta `O` opaque-token
//!   rule. An explicit token outranks a prefix; unmatched traffic falls
//!   to the built-in default tenant (id 0). Attribution is allocation-
//!   free: one relaxed atomic load when no tenants are defined, one
//!   rules read-lock + prefix compare when they are — the get hit path
//!   stays zero-alloc (`tests/hotpath_alloc.rs`).
//! * **Accounting** — per-tenant hit/miss/set counters, live byte and
//!   item gauges maintained by the store through the
//!   [`TenantSink`](crate::store::store::TenantSink) hooks (every
//!   insert/free path reports the stamped owner), and a per-tenant
//!   [`SizeCollector`] fed from the write path so the optimizer can
//!   learn per-tenant geometry.
//! * **Arbitration** — soft page quotas plus "need"-based reallocation:
//!   tenants over quota, and the lowest-need tenant when another
//!   tenant's marginal need (window miss rate per live byte) dwarfs
//!   it, are marked for bounded cold-tail reclaim
//!   (`KvStore::reclaim_tenants`), driven from the background
//!   maintainer — never stop-the-world. Freed chunks and pages return
//!   through the allocator's normal free-page pool, where the needy
//!   tenant's writes (and any in-flight incremental migration) pick
//!   them up.

use crate::optimizer::collector::SizeCollector;
use crate::store::store::TenantSink;
use crate::util::histogram::SizeHistogram;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

/// Hard cap on tenants (ids fit a `u8` stamp in `ItemMeta` and a `u64`
/// arbitration bitmask; 16 keeps the per-tenant collector memory
/// bounded).
pub const MAX_TENANTS: usize = 16;

/// The built-in tenant for unmatched traffic.
pub const DEFAULT_TENANT: u8 = 0;

/// Default maintainer passes between arbitration evaluations.
pub const DEFAULT_ARBITRATE_EVERY: u64 = 10;

/// Default per-shard item budget of one arbitration reclaim.
pub const DEFAULT_RECLAIM_BATCH: usize = 256;

/// Default per-tenant histogram divergence (total-variation distance)
/// above which the optimizer learns per-tenant geometry.
pub const DEFAULT_DIVERGENCE: f64 = 0.25;

/// Need ratio (max tenant need / min tenant need) above which the
/// low-need tenant donates pages even without quota overage.
const NEED_RATIO: f64 = 8.0;

/// One configured tenant: name, key-prefix rule, soft page quota
/// (0 = unlimited).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantSpec {
    pub name: String,
    pub prefix: Vec<u8>,
    pub quota_pages: u64,
}

impl TenantSpec {
    /// Parse a CLI/TOML tenant list: `name=prefix[:quota_pages]`,
    /// comma-separated (`app=app_:64,img=img_`). Prefixes may not
    /// contain `,`, `=`, or `:` in this compact form — use the runtime
    /// `tenants define` command for exotic prefixes.
    pub fn parse_list(s: &str) -> Result<Vec<TenantSpec>, String> {
        let mut out = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (name, rest) = part
                .split_once('=')
                .ok_or_else(|| format!("tenant '{part}': expected name=prefix[:quota]"))?;
            let (prefix, quota) = match rest.split_once(':') {
                Some((p, q)) => (
                    p,
                    q.parse::<u64>()
                        .map_err(|_| format!("tenant '{name}': bad quota '{q}'"))?,
                ),
                None => (rest, 0),
            };
            if name.is_empty() || prefix.is_empty() {
                return Err(format!("tenant '{part}': empty name or prefix"));
            }
            out.push(TenantSpec {
                name: name.to_string(),
                prefix: prefix.as_bytes().to_vec(),
                quota_pages: quota,
            });
        }
        Ok(out)
    }
}

/// Mutable rule state behind the registry's `RwLock`.
struct Rules {
    /// Tenant names; index = tenant id. `[0]` is the default tenant.
    names: Vec<String>,
    /// Key-prefix rules, sorted longest-prefix-first so the first
    /// match is the most specific.
    prefixes: Vec<(Vec<u8>, u8)>,
    /// Meta `O` opaque-token rules (exact match; outrank prefixes).
    tokens: Vec<(Vec<u8>, u8)>,
    /// Soft page quotas, parallel to `names` (0 = unlimited).
    quotas: Vec<u64>,
}

/// Per-tenant atomic counters. Cumulative counters reset with
/// `stats reset`; the live gauges (`bytes_live`, `items_live`) do not —
/// they mirror what is resident in the slabs right now.
#[derive(Default)]
struct TenantCounters {
    gets: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    sets: AtomicU64,
    bytes_live: AtomicU64,
    items_live: AtomicU64,
    bytes_written: AtomicU64,
    evictions: AtomicU64,
    quota_evictions: AtomicU64,
    /// Arbitration-window baselines (cumulative values at the last
    /// `arbitration_mask` evaluation).
    win_gets: AtomicU64,
    win_misses: AtomicU64,
}

/// Snapshot row for `stats tenants`.
#[derive(Clone, Debug, Default)]
pub struct TenantStat {
    pub id: u8,
    pub name: String,
    pub gets: u64,
    pub hits: u64,
    pub misses: u64,
    pub sets: u64,
    pub bytes_live: u64,
    pub items_live: u64,
    pub bytes_written: u64,
    pub evictions: u64,
    pub quota_evictions: u64,
    pub quota_pages: u64,
    pub used_pages: u64,
}

/// Snapshot row for the `tenants list` admin command.
#[derive(Clone, Debug)]
pub struct TenantRule {
    pub id: u8,
    pub name: String,
    pub prefixes: Vec<Vec<u8>>,
    pub tokens: Vec<Vec<u8>>,
    pub quota_pages: u64,
}

/// The tenant registry: rules + counters + per-tenant size collectors.
/// One per [`ShardedStore`](crate::store::sharded::ShardedStore); also
/// the store's [`TenantSink`], so byte accounting flows from the same
/// insert/free paths that keep the slab stats honest.
pub struct TenantRegistry {
    /// False until a non-default tenant is defined: attribution and
    /// per-request counting short-circuit to one relaxed load, so a
    /// single-tenant server pays nothing for this layer.
    active: AtomicBool,
    page_size: usize,
    /// f64 bits (atomics keep the tuning knobs settable after the
    /// registry is shared).
    divergence_threshold: AtomicU64,
    reclaim_batch: AtomicUsize,
    rules: RwLock<Rules>,
    counters: Vec<TenantCounters>,
    collectors: Vec<Arc<SizeCollector>>,
}

impl TenantRegistry {
    /// An inactive registry (default tenant only).
    pub fn new(page_size: usize) -> Self {
        Self::with_settings(page_size, &[], DEFAULT_DIVERGENCE, DEFAULT_RECLAIM_BATCH)
            .expect("empty spec list is always valid")
    }

    /// Build from configured specs plus the arbitration knobs.
    pub fn with_settings(
        page_size: usize,
        specs: &[TenantSpec],
        divergence_threshold: f64,
        reclaim_batch: usize,
    ) -> Result<Self, String> {
        let reg = TenantRegistry {
            active: AtomicBool::new(false),
            page_size: page_size.max(1),
            divergence_threshold: AtomicU64::new(divergence_threshold.to_bits()),
            reclaim_batch: AtomicUsize::new(reclaim_batch.max(1)),
            rules: RwLock::new(Rules {
                names: vec!["default".to_string()],
                prefixes: Vec::new(),
                tokens: Vec::new(),
                quotas: vec![0],
            }),
            counters: (0..MAX_TENANTS).map(|_| TenantCounters::default()).collect(),
            collectors: (0..MAX_TENANTS)
                .map(|_| Arc::new(SizeCollector::default()))
                .collect(),
        };
        for s in specs {
            reg.define(&s.name, &s.prefix, Some(s.quota_pages))?;
        }
        Ok(reg)
    }

    /// True once any non-default tenant exists.
    #[inline]
    pub fn active(&self) -> bool {
        self.active.load(Ordering::Relaxed)
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn divergence_threshold(&self) -> f64 {
        f64::from_bits(self.divergence_threshold.load(Ordering::Relaxed))
    }

    /// Per-shard item budget for one arbitration reclaim pass.
    pub fn reclaim_batch(&self) -> usize {
        self.reclaim_batch.load(Ordering::Relaxed)
    }

    /// Adjust the tuning knobs (config wiring after construction).
    pub fn set_tuning(&self, divergence_threshold: f64, reclaim_batch: usize) {
        self.divergence_threshold
            .store(divergence_threshold.to_bits(), Ordering::Relaxed);
        self.reclaim_batch
            .store(reclaim_batch.max(1), Ordering::Relaxed);
    }

    fn id_of(rules: &Rules, name: &str) -> Option<u8> {
        rules.names.iter().position(|n| n == name).map(|i| i as u8)
    }

    /// Define (or update) a tenant with a key-prefix rule and an
    /// optional quota. Returns the tenant id. Existing traffic keeps
    /// its stamped owner — a rule only affects attribution of **new**
    /// requests.
    pub fn define(
        &self,
        name: &str,
        prefix: &[u8],
        quota_pages: Option<u64>,
    ) -> Result<u8, String> {
        if name.is_empty() || name == "default" {
            return Err("tenant name must be non-empty and not 'default'".into());
        }
        if prefix.is_empty() {
            return Err("tenant prefix must be non-empty".into());
        }
        let mut r = self.rules.write().unwrap();
        let id = match Self::id_of(&r, name) {
            Some(id) => id,
            None => {
                if r.names.len() >= MAX_TENANTS {
                    return Err(format!("tenant limit reached ({MAX_TENANTS})"));
                }
                r.names.push(name.to_string());
                r.quotas.push(0);
                (r.names.len() - 1) as u8
            }
        };
        r.prefixes.retain(|(p, _)| p != prefix);
        r.prefixes.push((prefix.to_vec(), id));
        r.prefixes.sort_by(|a, b| b.0.len().cmp(&a.0.len()));
        if let Some(q) = quota_pages {
            r.quotas[id as usize] = q;
        }
        self.active.store(true, Ordering::Release);
        Ok(id)
    }

    /// Bind a meta `O` opaque token to an existing tenant (exact match;
    /// outranks any prefix rule).
    pub fn set_token(&self, name: &str, token: &[u8]) -> Result<u8, String> {
        if token.is_empty() {
            return Err("token must be non-empty".into());
        }
        let mut r = self.rules.write().unwrap();
        let id = Self::id_of(&r, name).ok_or_else(|| format!("unknown tenant '{name}'"))?;
        r.tokens.retain(|(t, _)| t != token);
        r.tokens.push((token.to_vec(), id));
        Ok(id)
    }

    /// Set a tenant's soft quota in pages (0 = unlimited).
    pub fn set_quota(&self, name: &str, pages: u64) -> Result<u8, String> {
        let mut r = self.rules.write().unwrap();
        let id = Self::id_of(&r, name).ok_or_else(|| format!("unknown tenant '{name}'"))?;
        r.quotas[id as usize] = pages;
        Ok(id)
    }

    /// Attribute a request: explicit meta `O` token first, then the
    /// longest matching key prefix, else the default tenant.
    /// Allocation-free; `opaque` is empty for classic-protocol
    /// requests.
    #[inline]
    pub fn attribute(&self, key: &[u8], opaque: &[u8]) -> u8 {
        if !self.active() {
            return DEFAULT_TENANT;
        }
        let r = self.rules.read().unwrap();
        if !opaque.is_empty() {
            for (tok, id) in &r.tokens {
                if tok.as_slice() == opaque {
                    return *id;
                }
            }
        }
        for (p, id) in &r.prefixes {
            if key.starts_with(p) {
                return *id;
            }
        }
        DEFAULT_TENANT
    }

    /// Count one get (hit or miss) against a tenant.
    #[inline]
    pub fn record_get(&self, tenant: u8, hit: bool) {
        let c = &self.counters[tenant as usize % MAX_TENANTS];
        c.gets.fetch_add(1, Ordering::Relaxed);
        if hit {
            c.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            c.misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one storage command against a tenant.
    #[inline]
    pub fn record_set(&self, tenant: u8) {
        self.counters[tenant as usize % MAX_TENANTS]
            .sets
            .fetch_add(1, Ordering::Relaxed);
    }

    /// The per-tenant size histogram collector (the optimizer's
    /// per-tenant learning input).
    pub fn collector(&self, tenant: u8) -> &Arc<SizeCollector> {
        &self.collectors[tenant as usize % MAX_TENANTS]
    }

    /// Per-tenant histograms with at least `min_total` samples, for
    /// the per-tenant geometry pass. Only defined tenants are reported.
    pub fn tenant_histograms(&self, min_total: u64) -> Vec<(u8, SizeHistogram)> {
        let n = self.rules.read().unwrap().names.len();
        (0..n)
            .filter(|&i| self.collectors[i].total() >= min_total.max(1))
            .map(|i| (i as u8, self.collectors[i].snapshot()))
            .collect()
    }

    fn used_pages(&self, id: usize) -> u64 {
        self.counters[id].bytes_live.load(Ordering::Relaxed) / self.page_size as u64
    }

    /// Evaluate arbitration: a bitmask of tenants to reclaim from.
    ///
    /// Two triggers, Memshare-style:
    /// 1. **Quota**: any tenant whose live bytes exceed its soft page
    ///    quota.
    /// 2. **Need**: need = window misses per live byte — the marginal
    ///    benefit proxy (a tenant missing a lot relative to its
    ///    footprint gains the most from extra memory; one holding many
    ///    bytes it rarely misses on gains the least). When the neediest
    ///    tenant's need exceeds `NEED_RATIO`× the least needy holder's,
    ///    the low-need tenant donates from its cold tail.
    ///
    /// Also advances the per-tenant need window. Returns 0 when
    /// inactive or nothing should move.
    pub fn arbitration_mask(&self) -> u64 {
        if !self.active() {
            return 0;
        }
        let (n, quotas) = {
            let r = self.rules.read().unwrap();
            (r.names.len(), r.quotas.clone())
        };
        let mut mask = 0u64;
        let mut needs: Vec<(usize, f64, u64)> = Vec::with_capacity(n);
        for id in 0..n {
            let c = &self.counters[id];
            if quotas[id] > 0 && self.used_pages(id) > quotas[id] {
                mask |= 1 << id;
            }
            let gets = c.gets.load(Ordering::Relaxed);
            let misses = c.misses.load(Ordering::Relaxed);
            let wgets = c.win_gets.swap(gets, Ordering::Relaxed);
            let wmiss = c.win_misses.swap(misses, Ordering::Relaxed);
            let dgets = gets.saturating_sub(wgets);
            let dmiss = misses.saturating_sub(wmiss);
            let bytes = c.bytes_live.load(Ordering::Relaxed);
            if dgets > 0 {
                needs.push((id, dmiss as f64 / bytes.max(1) as f64, bytes));
            }
        }
        // need-based donation: only tenants holding at least one page
        // can donate, and only when the spread is decisive
        if needs.len() >= 2 {
            let (max_id, max_need, _) = *needs
                .iter()
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            let donors: Vec<&(usize, f64, u64)> = needs
                .iter()
                .filter(|&&(id, _, bytes)| id != max_id && bytes >= self.page_size as u64)
                .collect();
            if let Some(&&(min_id, min_need, _)) = donors
                .iter()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            {
                if max_need > NEED_RATIO * (min_need + 1e-12) {
                    mask |= 1 << min_id;
                }
            }
        }
        mask
    }

    /// Snapshot for `stats tenants` (defined tenants only, id order).
    pub fn stats_snapshot(&self) -> Vec<TenantStat> {
        let r = self.rules.read().unwrap();
        (0..r.names.len())
            .map(|id| {
                let c = &self.counters[id];
                TenantStat {
                    id: id as u8,
                    name: r.names[id].clone(),
                    gets: c.gets.load(Ordering::Relaxed),
                    hits: c.hits.load(Ordering::Relaxed),
                    misses: c.misses.load(Ordering::Relaxed),
                    sets: c.sets.load(Ordering::Relaxed),
                    bytes_live: c.bytes_live.load(Ordering::Relaxed),
                    items_live: c.items_live.load(Ordering::Relaxed),
                    bytes_written: c.bytes_written.load(Ordering::Relaxed),
                    evictions: c.evictions.load(Ordering::Relaxed),
                    quota_evictions: c.quota_evictions.load(Ordering::Relaxed),
                    quota_pages: r.quotas[id],
                    used_pages: c.bytes_live.load(Ordering::Relaxed) / self.page_size as u64,
                }
            })
            .collect()
    }

    /// Snapshot of the rule tables for `tenants list`.
    pub fn rules_snapshot(&self) -> Vec<TenantRule> {
        let r = self.rules.read().unwrap();
        (0..r.names.len())
            .map(|id| TenantRule {
                id: id as u8,
                name: r.names[id].clone(),
                prefixes: r
                    .prefixes
                    .iter()
                    .filter(|(_, t)| *t as usize == id)
                    .map(|(p, _)| p.clone())
                    .collect(),
                tokens: r
                    .tokens
                    .iter()
                    .filter(|(_, t)| *t as usize == id)
                    .map(|(t, _)| t.clone())
                    .collect(),
                quota_pages: r.quotas[id],
            })
            .collect()
    }

    /// `stats reset`: zero the cumulative counters and size histograms
    /// **without dropping rules** and without touching the live gauges
    /// (`bytes_live`/`items_live` mirror resident memory, not history).
    pub fn reset_counters(&self) {
        for c in &self.counters {
            c.gets.store(0, Ordering::Relaxed);
            c.hits.store(0, Ordering::Relaxed);
            c.misses.store(0, Ordering::Relaxed);
            c.sets.store(0, Ordering::Relaxed);
            c.bytes_written.store(0, Ordering::Relaxed);
            c.evictions.store(0, Ordering::Relaxed);
            c.quota_evictions.store(0, Ordering::Relaxed);
            c.win_gets.store(0, Ordering::Relaxed);
            c.win_misses.store(0, Ordering::Relaxed);
        }
        for col in &self.collectors {
            col.reset();
        }
    }
}

impl TenantSink for TenantRegistry {
    fn on_store(&self, tenant: u8, total: usize) {
        let c = &self.counters[tenant as usize % MAX_TENANTS];
        c.bytes_live.fetch_add(total as u64, Ordering::Relaxed);
        c.items_live.fetch_add(1, Ordering::Relaxed);
        c.bytes_written.fetch_add(total as u64, Ordering::Relaxed);
        if self.active() {
            self.collectors[tenant as usize % MAX_TENANTS].record(total);
        }
    }

    fn on_free(&self, tenant: u8, total: usize) {
        let c = &self.counters[tenant as usize % MAX_TENANTS];
        c.bytes_live.fetch_sub(total as u64, Ordering::Relaxed);
        c.items_live.fetch_sub(1, Ordering::Relaxed);
    }

    fn on_evict(&self, tenant: u8, quota: bool) {
        let c = &self.counters[tenant as usize % MAX_TENANTS];
        c.evictions.fetch_add(1, Ordering::Relaxed);
        if quota {
            c.quota_evictions.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Total-variation distance between two size distributions, over 64
/// coarse 256-byte buckets. 0 = identical, 1 = disjoint. The
/// per-tenant geometry pass runs when the max pairwise divergence
/// clears the registry threshold.
pub fn histogram_divergence(a: &SizeHistogram, b: &SizeHistogram) -> f64 {
    let (ta, tb) = (a.total_items(), b.total_items());
    if ta == 0 || tb == 0 {
        return 0.0;
    }
    let mut pa = [0f64; 64];
    let mut pb = [0f64; 64];
    for (s, c) in a.iter() {
        pa[(s / 256).min(63)] += c as f64 / ta as f64;
    }
    for (s, c) in b.iter() {
        pb[(s / 256).min(63)] += c as f64 / tb as f64;
    }
    0.5 * pa.iter().zip(pb.iter()).map(|(x, y)| (x - y).abs()).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> TenantRegistry {
        TenantRegistry::new(1 << 20)
    }

    #[test]
    fn inactive_registry_attributes_everything_to_default() {
        let r = reg();
        assert!(!r.active());
        assert_eq!(r.attribute(b"app_k1", b""), DEFAULT_TENANT);
        assert_eq!(r.attribute(b"anything", b"tok"), DEFAULT_TENANT);
        assert_eq!(r.arbitration_mask(), 0);
    }

    #[test]
    fn longest_prefix_wins() {
        let r = reg();
        let a = r.define("app", b"app_", None).unwrap();
        let ab = r.define("app-big", b"app_big_", None).unwrap();
        assert!(r.active());
        assert_eq!(r.attribute(b"app_k", b""), a);
        assert_eq!(r.attribute(b"app_big_k", b""), ab);
        assert_eq!(r.attribute(b"other", b""), DEFAULT_TENANT);
    }

    #[test]
    fn opaque_token_outranks_prefix() {
        let r = reg();
        let a = r.define("app", b"app_", None).unwrap();
        let b = r.define("batch", b"batch_", None).unwrap();
        r.set_token("batch", b"BATCHTOK").unwrap();
        // key matches app's prefix, but the token says batch
        assert_eq!(r.attribute(b"app_k", b"BATCHTOK"), b);
        // unknown token falls back to the prefix
        assert_eq!(r.attribute(b"app_k", b"WHO"), a);
    }

    #[test]
    fn define_updates_in_place_and_caps_at_max() {
        let r = reg();
        let id = r.define("app", b"app_", Some(4)).unwrap();
        assert_eq!(r.define("app", b"app2_", None).unwrap(), id);
        let rules = r.rules_snapshot();
        let app = &rules[id as usize];
        assert_eq!(app.prefixes.len(), 2);
        assert_eq!(app.quota_pages, 4);
        for i in 0..MAX_TENANTS - 2 {
            r.define(&format!("t{i}"), format!("t{i}_").as_bytes(), None)
                .unwrap();
        }
        assert!(r.define("overflow", b"x_", None).is_err());
        assert!(r.define("default", b"d_", None).is_err());
    }

    #[test]
    fn sink_accounting_balances() {
        let r = reg();
        let a = r.define("app", b"app_", None).unwrap();
        r.on_store(a, 600);
        r.on_store(a, 400);
        r.on_free(a, 600);
        let s = &r.stats_snapshot()[a as usize];
        assert_eq!(s.bytes_live, 400);
        assert_eq!(s.items_live, 1);
        assert_eq!(s.bytes_written, 1000);
        assert_eq!(r.collector(a).total(), 2, "collector fed from writes");
    }

    #[test]
    fn reset_clears_counters_keeps_rules_and_gauges() {
        let r = reg();
        let a = r.define("app", b"app_", Some(2)).unwrap();
        r.record_get(a, true);
        r.record_get(a, false);
        r.record_set(a);
        r.on_store(a, 512);
        r.on_evict(a, true);
        r.reset_counters();
        let s = &r.stats_snapshot()[a as usize];
        assert_eq!((s.gets, s.hits, s.misses, s.sets), (0, 0, 0, 0));
        assert_eq!((s.evictions, s.quota_evictions, s.bytes_written), (0, 0, 0));
        assert_eq!(s.bytes_live, 512, "live gauge survives reset");
        assert_eq!(s.items_live, 1);
        assert_eq!(s.quota_pages, 2, "rules survive reset");
        assert_eq!(r.attribute(b"app_k", b""), a, "attribution survives reset");
        assert_eq!(r.collector(a).total(), 0, "histogram resets");
    }

    #[test]
    fn quota_overage_sets_mask_bit() {
        let r = TenantRegistry::new(1024);
        let a = r.define("app", b"app_", Some(2)).unwrap();
        r.on_store(a, 4096); // 4 pages live > 2-page quota
        assert_eq!(r.arbitration_mask() & (1 << a), 1 << a);
        r.on_free(a, 4096);
        r.on_store(a, 1024);
        assert_eq!(r.arbitration_mask() & (1 << a), 0);
    }

    #[test]
    fn need_spread_marks_low_need_holder() {
        let r = TenantRegistry::new(1024);
        let a = r.define("needy", b"a_", None).unwrap();
        let b = r.define("hoarder", b"b_", None).unwrap();
        // hoarder: lots of bytes, no misses; needy: few bytes, misses
        r.on_store(b, 64 * 1024);
        r.on_store(a, 512);
        r.arbitration_mask(); // open the window
        for _ in 0..100 {
            r.record_get(a, false);
        }
        for _ in 0..100 {
            r.record_get(b, true);
        }
        let mask = r.arbitration_mask();
        assert_eq!(mask & (1 << b), 1 << b, "hoarder donates");
        assert_eq!(mask & (1 << a), 0, "needy keeps its memory");
    }

    #[test]
    fn spec_list_parses() {
        let specs = TenantSpec::parse_list("app=app_:64, img=img_ ,").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "app");
        assert_eq!(specs[0].prefix, b"app_");
        assert_eq!(specs[0].quota_pages, 64);
        assert_eq!(specs[1].quota_pages, 0);
        assert!(TenantSpec::parse_list("noequals").is_err());
        assert!(TenantSpec::parse_list("a=p:zzz").is_err());
        assert!(TenantSpec::parse_list("=p").is_err());
    }

    #[test]
    fn divergence_detects_disjoint_and_identical() {
        let mut a = SizeHistogram::new(16384);
        let mut b = SizeHistogram::new(16384);
        for _ in 0..1000 {
            a.record(120);
            b.record(9000);
        }
        assert!(histogram_divergence(&a, &b) > 0.9, "disjoint sizes");
        assert!(histogram_divergence(&a, &a) < 1e-9, "identical");
        let empty = SizeHistogram::new(64);
        assert_eq!(histogram_divergence(&a, &empty), 0.0);
    }
}
