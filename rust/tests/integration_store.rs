//! Whole-store integration: workload generator → sharded store →
//! optimizer → live reconfiguration, exercising the full in-process
//! stack the way `examples/live_retune.rs` does over TCP.

use slabforge::config::settings::Algorithm;
use slabforge::optimizer::collector::SizeCollector;
use slabforge::optimizer::engine::{optimize, OptimizerParams, RustBackend};
use slabforge::optimizer::waste::WasteMap;
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::Clock;
use slabforge::store::{spawn_maintainer, MaintainerConfig};
use slabforge::util::failpoint;
use slabforge::workload::spec::SizeDistribution;
use slabforge::workload::{Op, WorkloadGen, WorkloadSpec};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn store(mem: usize, shards: usize) -> Arc<ShardedStore> {
    Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            mem,
            true,
            shards,
            Clock::System,
        )
        .unwrap(),
    )
}

fn drive(store: &ShardedStore, spec: WorkloadSpec) -> (u64, u64) {
    let gen = WorkloadGen::new(spec, true);
    let (mut sets, mut gets) = (0u64, 0u64);
    for op in gen {
        match op {
            Op::Set { key, value_len } => {
                // OutOfMemory is legal under pressure (memcached returns
                // SERVER_ERROR when a class has no page and no victims)
                match store.set(key.as_bytes(), &vec![b'v'; value_len], 0, 0) {
                    Ok(()) | Err(slabforge::store::store::StoreError::OutOfMemory) => {}
                    Err(e) => panic!("set failed: {e}"),
                }
                sets += 1;
            }
            Op::Get { key } => {
                store.get(key.as_bytes());
                gets += 1;
            }
            Op::Delete { key } => {
                store.delete(key.as_bytes());
            }
        }
    }
    (sets, gets)
}

fn t1_spec(items: usize) -> WorkloadSpec {
    WorkloadSpec {
        distribution: SizeDistribution::LogNormal {
            median: 518.0,
            sigma_ln: 0.126,
        },
        items,
        get_fraction: 0.0,
        key_space: items,
        zipf_s: 0.0,
        min_size: 70,
        max_size: 16384,
        seed: 101,
    }
}

#[test]
fn paper_t1_pipeline_insert_learn_reconfigure() {
    let store = store(128 << 20, 4);
    let collector = Arc::new(SizeCollector::default());
    store.set_observer(collector.clone());

    let (sets, _) = drive(&store, t1_spec(50_000));
    assert_eq!(sets, 50_000);
    assert_eq!(collector.total(), 50_000);

    let slabs_before = store.slab_stats();
    // the paper's §1 claim: ~10 % waste on log-normal traffic
    let frac = slabs_before.hole_fraction();
    assert!(
        (0.05..0.20).contains(&frac),
        "default-config hole fraction {frac}"
    );

    // learn + apply
    let hist = collector.snapshot();
    let backend = RustBackend::new(WasteMap::from_histogram(&hist));
    let report = optimize(
        &backend,
        &hist,
        &store.chunk_sizes(),
        &OptimizerParams {
            algorithm: Algorithm::SteepestDescent,
            ..Default::default()
        },
    );
    assert!(report.recovery() > 0.3, "recovery {}", report.recovery());

    let sizes: Vec<usize> = report.new_config.iter().map(|&c| c as usize).collect();
    let migs = store
        .reconfigure(ChunkSizePolicy::Explicit(sizes))
        .unwrap();
    assert_eq!(migs.iter().map(|m| m.items_dropped).sum::<usize>(), 0);

    let slabs_after = store.slab_stats();
    let live_recovery =
        1.0 - slabs_after.hole_bytes as f64 / slabs_before.hole_bytes as f64;
    // live migration must realize (approximately) the predicted savings
    assert!(
        (live_recovery - report.recovery()).abs() < 0.05,
        "predicted {} vs live {live_recovery}",
        report.recovery()
    );

    // all keys still readable with intact values
    for i in (0..50_000).step_by(4999) {
        let key = format!("k{i:08}");
        assert!(store.get(key.as_bytes()).is_some(), "lost {key}");
    }
}

#[test]
fn mixed_workload_with_gets_after_reconfigure() {
    let store = store(64 << 20, 2);
    let spec = WorkloadSpec {
        get_fraction: 0.5,
        zipf_s: 0.99,
        ..t1_spec(20_000)
    };
    drive(&store, spec);
    let stats = store.stats();
    assert!(stats.get_hits > 0, "zipf gets should hit");
    // reconfigure mid-life and keep serving
    store
        .reconfigure(ChunkSizePolicy::Explicit(vec![480, 520, 560, 620, 720, 950]))
        .unwrap();
    let spec2 = WorkloadSpec {
        get_fraction: 0.9,
        seed: 202,
        ..t1_spec(5_000)
    };
    drive(&store, spec2);
    let stats2 = store.stats();
    assert!(stats2.get_hits > stats.get_hits);
}

fn small_page_store(mem: usize, shards: usize) -> Arc<ShardedStore> {
    // 64 KiB pages: a tight budget still leaves every engaged class a
    // page (with 1 MiB pages and ~2 pages per shard, a fresh class has
    // no page and nothing to evict — memcached 1.4 semantics, which we
    // reproduce — so pressure tests use smaller pages)
    Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            64 << 10,
            mem,
            true,
            shards,
            Clock::System,
        )
        .unwrap(),
    )
}

#[test]
fn eviction_pressure_with_undersized_cache() {
    // 4 MiB cache, ~518-byte items: capacity ≈ 8k items; we insert 40k
    let store = small_page_store(4 << 20, 2);
    drive(&store, t1_spec(40_000));
    let stats = store.stats();
    assert!(stats.evictions > 10_000, "evictions {}", stats.evictions);
    // memory stays within budget
    let slabs = store.slab_stats();
    assert!(slabs.pages_allocated <= slabs.page_budget);
    // most recent keys survive
    assert!(store.get(b"k00039999").is_some());
}

#[test]
fn reconfigure_under_eviction_pressure_drops_nothing_vital() {
    let store = small_page_store(4 << 20, 1);
    drive(&store, t1_spec(20_000));
    let live_before = store.len();
    // Run the reconfigure with a live maintainer thread, the way a
    // real server does — but hold it quiescent at its
    // `maintainer.pass.pause` sync point instead of sleeping and
    // hoping it lands between passes. The thread is provably between
    // passes for the whole accounting window, so the drop/moved
    // bookkeeping below is deterministic (this replaced a flaky
    // sleep-based variant).
    let pause = failpoint::armed("maintainer.pass.pause", "pause").unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let maint = spawn_maintainer(store.clone(), MaintainerConfig::default(), stop.clone());
    let migs = store
        .reconfigure(ChunkSizePolicy::Explicit(vec![520, 620, 950]))
        .unwrap();
    let moved: usize = migs.iter().map(|m| m.items_moved).sum();
    let dropped: usize = migs.iter().map(|m| m.items_dropped).sum();
    assert_eq!(moved + dropped, live_before);
    // Deterministic drop accounting via the per-page index: a
    // force-drain drops exactly the residents of the pages it
    // enumerates, so every drop is attributable — either counted
    // against a force-drained page or the terminal no-room fallback
    // (bounded by one page's worth of items: only the in-flight item's
    // own pinned page can refuse to drain).
    let g = store.migration_gauges();
    assert_eq!(
        g.dropped,
        dropped as u64,
        "gauges and reports must agree"
    );
    let fallback = g.dropped - g.force_dropped;
    let max_chunks_per_page = (64 << 10) / 96; // smallest default class
    assert!(
        fallback <= max_chunks_per_page,
        "fallback drops {fallback} exceed one page's residents"
    );
    // tighter packing should not need to drop more than a sliver
    assert!(
        dropped * 20 <= live_before,
        "dropped {dropped} of {live_before}"
    );
    // Unblock before joining: the thread is parked at the pause point,
    // so the stop flag alone would leave it waiting out the pause cap.
    stop.store(true, Ordering::SeqCst);
    drop(pause);
    maint.join().unwrap();
    store.check_integrity().unwrap();
}

#[test]
fn flush_then_relearn_from_new_pattern() {
    let store = store(64 << 20, 2);
    let collector = Arc::new(SizeCollector::default());
    store.set_observer(collector.clone());

    drive(&store, t1_spec(10_000));
    store.flush_all();
    collector.reset();
    assert_eq!(store.len(), 0);

    // new pattern: fixed-size items (§6.1 best case)
    let spec = WorkloadSpec {
        distribution: SizeDistribution::Fixed { size: 777 },
        ..t1_spec(5_000)
    };
    drive(&store, spec);
    let hist = collector.snapshot();
    assert_eq!(hist.distinct_sizes(), 1);
    let backend = RustBackend::new(WasteMap::from_histogram(&hist));
    let report = optimize(
        &backend,
        &hist,
        &store.chunk_sizes(),
        &OptimizerParams::default(),
    );
    assert_eq!(report.new_waste, 0, "single size -> exact fit -> zero waste");
}
