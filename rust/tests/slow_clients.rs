//! Adversarially slow clients against the epoll reactor: dribbled
//! input (one byte per readiness event), stalled readers mid-response
//! (output backpressure + writev partial sends), and idle-connection
//! reaping. Every test asserts byte-exact, in-order output — the
//! reactor must never tear, reorder, or drop a response no matter how
//! the client paces I/O.

use slabforge::client::Client;
use slabforge::server::{Server, ServerHandle};
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::Clock;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn store() -> Arc<ShardedStore> {
    Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            64 << 20,
            true,
            2,
            Clock::System,
        )
        .unwrap(),
    )
}

fn start() -> ServerHandle {
    Server::new(store()).start("127.0.0.1:0").unwrap()
}

/// Deterministic value payload so any corruption is visible.
fn patterned(len: usize) -> Vec<u8> {
    (0..len).map(|i| (i % 251) as u8).collect()
}

fn read_until(s: &mut TcpStream, marker: &[u8]) -> Vec<u8> {
    let mut got = Vec::new();
    let mut buf = [0u8; 8192];
    while !got
        .windows(marker.len())
        .any(|w| w == marker)
    {
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "server closed early; got {} bytes", got.len());
        got.extend_from_slice(&buf[..n]);
    }
    got
}

/// A pipelined multiget dribbled one byte per socket write: the
/// reactor sees ~40 separate readiness events for one command line and
/// must reassemble it exactly, answering in request order.
#[test]
fn dribbled_multiget_reassembles_in_order() {
    let handle = start();
    let mut c = Client::connect(handle.addr()).unwrap();
    for k in ["wa", "wb", "wc"] {
        c.set(k, format!("val-{k}").as_bytes(), 0, 0).unwrap();
    }
    let mut s = TcpStream::connect(handle.addr()).unwrap();
    s.set_nodelay(true).unwrap();
    // two pipelined commands, dribbled byte-by-byte
    let script = b"get wc wa wb\r\nget wb\r\n";
    for &b in script.iter() {
        s.write_all(&[b]).unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let got = read_until(&mut s, b"END\r\nVALUE wb 0 6\r\nval-wb\r\nEND\r\n");
    assert_eq!(
        String::from_utf8_lossy(&got),
        "VALUE wc 0 6\r\nval-wc\r\nVALUE wa 0 6\r\nval-wa\r\nVALUE wb 0 6\r\nval-wb\r\nEND\r\n\
         VALUE wb 0 6\r\nval-wb\r\nEND\r\n"
    );
    handle.shutdown();
}

/// Large-value gets must be byte-identical through the writev scatter
/// path (values >= DIRECT_VALUE_MIN skip the chunk→buffer copy).
#[test]
fn writev_large_value_byte_identical() {
    let handle = start();
    let value = patterned(64 * 1024);
    let mut c = Client::connect(handle.addr()).unwrap();
    c.set("big64", &value, 7, 0).unwrap();

    let mut s = TcpStream::connect(handle.addr()).unwrap();
    s.write_all(b"get big64\r\n").unwrap();
    let mut expected = format!("VALUE big64 7 {}\r\n", value.len()).into_bytes();
    expected.extend_from_slice(&value);
    expected.extend_from_slice(b"\r\nEND\r\n");
    let mut got = vec![0u8; expected.len()];
    s.read_exact(&mut got).unwrap();
    assert_eq!(got, expected, "scattered response differs from reference");
    handle.shutdown();
}

/// A reader that stalls mid-response: 20 pipelined gets of a 600 KB
/// value (~12 MB of responses) with no reads for a while. The reactor
/// must hit the output high-water mark, yield (conn_yields ticks, no
/// busy-spin), re-register for EPOLLOUT, and still deliver every byte
/// in order once the client drains — through writev partial sends and
/// buffered tails.
#[test]
fn stalled_reader_gets_backpressured_not_corrupted() {
    let handle = start();
    let value = patterned(600_000);
    let mut c = Client::connect(handle.addr()).unwrap();
    c.set("big", &value, 0, 0).unwrap();

    let mut s = TcpStream::connect(handle.addr()).unwrap();
    const REPS: usize = 20;
    for _ in 0..REPS {
        s.write_all(b"get big\r\n").unwrap();
    }
    // stall: let the server run into a full socket + high-water mark
    std::thread::sleep(Duration::from_millis(400));
    assert!(
        handle.metrics.snapshot().conn_yields >= 1,
        "a stalled 12MB response stream must make the connection yield"
    );
    // drain slowly, in small chunks, and verify byte-exact output
    let mut one = format!("VALUE big 0 {}\r\n", value.len()).into_bytes();
    one.extend_from_slice(&value);
    one.extend_from_slice(b"\r\nEND\r\n");
    let mut expected = Vec::with_capacity(one.len() * REPS);
    for _ in 0..REPS {
        expected.extend_from_slice(&one);
    }
    let mut got = Vec::with_capacity(expected.len());
    let mut buf = [0u8; 8192];
    while got.len() < expected.len() {
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "server closed after {} of {} bytes", got.len(), expected.len());
        got.extend_from_slice(&buf[..n]);
        if got.len() % (1 << 20) < 8192 {
            std::thread::sleep(Duration::from_millis(1)); // keep it slow
        }
    }
    assert_eq!(got.len(), expected.len());
    assert!(got == expected, "response stream corrupted under backpressure");
    handle.shutdown();
}

/// Connections idle past the configured timeout are reaped, so
/// `quit`-less load generators cannot leak fds.
#[test]
fn idle_connections_are_reaped() {
    let handle = Server::new(store())
        .idle_timeout(Some(Duration::from_millis(300)))
        .start("127.0.0.1:0")
        .unwrap();
    let mut s = TcpStream::connect(handle.addr()).unwrap();
    s.write_all(b"version\r\n").unwrap();
    let _ = read_until(&mut s, b"\r\n");
    // go idle; the sweep (1s cadence) must close us
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = [0u8; 64];
    let n = s.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "server must close the idle connection");
    let deadline = Instant::now() + Duration::from_secs(2);
    while handle.metrics.snapshot().curr_connections > 0 {
        assert!(Instant::now() < deadline, "gauge never returned to zero");
        std::thread::sleep(Duration::from_millis(20));
    }
    handle.shutdown();
}

/// An active connection must NOT be reaped by the idle sweep.
#[test]
fn active_connection_survives_idle_sweep() {
    let handle = Server::new(store())
        .idle_timeout(Some(Duration::from_millis(500)))
        .start("127.0.0.1:0")
        .unwrap();
    let mut c = Client::connect(handle.addr()).unwrap();
    c.set("alive", b"yes", 0, 0).unwrap();
    let until = Instant::now() + Duration::from_secs(2);
    while Instant::now() < until {
        assert_eq!(
            c.get("alive").unwrap().unwrap().value,
            b"yes",
            "active connection was reaped"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
    handle.shutdown();
}
