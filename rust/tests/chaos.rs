//! Chaos suite: randomized and targeted failpoint schedules over live
//! traffic. Every schedule must end with (a) no aborts — injected
//! faults surface as clean protocol errors or clean closes, never a
//! process death; (b) `check_integrity()` green; (c) no hangs — every
//! loop here is bounded.
//!
//! The failpoint registry is process-global, so every test serializes
//! on [`serial`] and disarms everything on entry. Schedules that would
//! spin a retry loop forever (`migrate.step.fail=always`,
//! `conn.read.eintr=always`) are deliberately absent — the README
//! documents the same caveat for humans.

// the reactor (budget shedding, EMFILE relief, drain deadline) is the
// epoll back end — linux-only, like `server::sys`
#![cfg(target_os = "linux")]

use slabforge::client::Client;
use slabforge::config::settings::OptimizerSettings;
use slabforge::optimizer::autotune::AutoTuner;
use slabforge::optimizer::collector::SizeCollector;
use slabforge::server::{Control, Server, ServerHandle};
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::{Clock, StoreError};
use slabforge::store::{spawn_maintainer, MaintainerConfig};
use slabforge::util::failpoint;
use slabforge::util::rng::Pcg64;
use slabforge::util::supervisor;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// One registry, one test at a time (arming is process-global).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    let g = SERIAL.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    failpoint::disarm_all();
    g
}

fn store(mem: usize, page: usize, shards: usize) -> Arc<ShardedStore> {
    Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            page,
            mem,
            true,
            shards,
            Clock::System,
        )
        .unwrap(),
    )
}

fn server(st: &Arc<ShardedStore>) -> ServerHandle {
    Server::new(st.clone()).start("127.0.0.1:0").unwrap()
}

fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

// ---------------------------------------------------- targeted schedules

#[test]
fn item_alloc_storm_surfaces_server_error_not_hangs() {
    let _g = serial();
    let st = store(16 << 20, PAGE_SIZE, 2);
    let h = server(&st);
    let _fp = failpoint::armed("store.item_alloc", "1in5").unwrap();
    let mut c = Client::connect(h.addr()).unwrap();
    let (mut ok, mut err) = (0u32, 0u32);
    for i in 0..100 {
        match c.set(&format!("ia{i}"), &vec![b'x'; 300], 0, 0) {
            Ok(()) => ok += 1,
            // clean SERVER_ERROR on the wire, connection stays in sync
            Err(e) => {
                assert!(format!("{e}").contains("SERVER_ERROR"), "{e}");
                err += 1;
            }
        }
    }
    assert!(ok > 0 && err > 0, "ok={ok} err={err}: storm must be partial");
    failpoint::disarm_all();
    c.set("after", b"storm", 0, 0).unwrap();
    assert_eq!(c.get("after").unwrap().unwrap().value, b"storm");
    st.check_integrity().unwrap();
    h.shutdown();
}

#[test]
fn page_alloc_storm_keeps_store_consistent() {
    let _g = serial();
    let st = store(8 << 20, PAGE_SIZE, 2);
    let _fp = failpoint::armed("slab.page_alloc", "1in3").unwrap();
    for i in 0..5000 {
        match st.set(format!("pa{i:05}").as_bytes(), &vec![b'p'; 2000], 0, 0) {
            // a missing page degrades to eviction or a clean refusal
            Ok(()) | Err(StoreError::OutOfMemory) => {}
            Err(e) => panic!("unexpected error under page-alloc storm: {e}"),
        }
    }
    assert!(failpoint::fire_count("slab.page_alloc") > 0);
    failpoint::disarm_all();
    st.check_integrity().unwrap();
    // storm over: the store still takes writes normally
    st.set(b"after", b"ok", 0, 0).unwrap();
    assert!(st.get(b"after").is_some());
}

#[test]
fn writev_fault_storm_delivers_intact_responses() {
    let _g = serial();
    let st = store(32 << 20, PAGE_SIZE, 2);
    let h = server(&st);
    let mut c = Client::connect(h.addr()).unwrap();
    let sizes = [100usize, 8_000, 64_000];
    for (i, n) in sizes.iter().enumerate() {
        let v = vec![b'a' + i as u8; *n];
        c.set(&format!("wv{i}"), &v, 0, 0).unwrap();
    }
    // short writes + spurious EAGAIN on every response from here on
    let _s = failpoint::armed("sys.writev.short", "1in5").unwrap();
    let _e = failpoint::armed("sys.writev.eagain", "1in7").unwrap();
    for round in 0..30 {
        let i = round % sizes.len();
        let v = c.get(&format!("wv{i}")).unwrap().unwrap().value;
        assert_eq!(v.len(), sizes[i], "round {round}");
        assert!(v.iter().all(|&b| b == b'a' + i as u8), "round {round}");
    }
    assert!(failpoint::fire_count("sys.writev.short") > 0);
    failpoint::disarm_all();
    st.check_integrity().unwrap();
    h.shutdown();
}

#[test]
fn read_eintr_storm_is_transparent() {
    let _g = serial();
    let st = store(16 << 20, PAGE_SIZE, 2);
    let h = server(&st);
    // never `always`: like a real EINTR storm, that would spin the
    // retry loop — the schedule must leave most reads clean
    let _fp = failpoint::armed("conn.read.eintr", "1in6").unwrap();
    let mut c = Client::connect(h.addr()).unwrap();
    for i in 0..50 {
        let key = format!("ei{i}");
        c.set(&key, key.as_bytes(), 0, 0).unwrap();
        assert_eq!(c.get(&key).unwrap().unwrap().value, key.as_bytes());
    }
    assert!(failpoint::fire_count("conn.read.eintr") > 0);
    failpoint::disarm_all();
    st.check_integrity().unwrap();
    h.shutdown();
}

#[test]
fn migrate_step_panic_is_resumed_by_supervised_maintainer() {
    let _g = serial();
    let st = store(16 << 20, PAGE_SIZE, 2);
    let n = 5000;
    for i in 0..n {
        let key = format!("mp{i:05}");
        st.set(key.as_bytes(), &vec![b'v'; 500], 0, 0).unwrap();
    }
    st.begin_reconfigure(ChunkSizePolicy::Explicit(vec![520, 620, 950]))
        .unwrap();
    let before = supervisor::thread_restarts();
    // first pump step dies; the supervisor must log, count, respawn,
    // and the next pass must pick the drain back up where it parked
    let _fp = failpoint::armed("migrate.step.panic", "once").unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let maint = spawn_maintainer(
        st.clone(),
        MaintainerConfig {
            interval_ms: 2,
            ..MaintainerConfig::default()
        },
        stop.clone(),
    );
    assert!(
        wait_until(Duration::from_secs(20), || !st.migration_active()),
        "drain never completed after injected panic"
    );
    assert!(
        supervisor::thread_restarts() > before,
        "panic was not routed through the supervisor"
    );
    stop.store(true, Ordering::SeqCst);
    maint.join().unwrap();
    let g = st.migration_gauges();
    assert_eq!(g.dropped, 0, "ample memory: nothing may drop");
    assert_eq!(st.len(), n, "every item survived the panicked drain");
    st.check_integrity().unwrap();
}

#[test]
fn migrate_step_fail_storm_still_converges() {
    let _g = serial();
    let st = store(16 << 20, PAGE_SIZE, 2);
    for i in 0..3000 {
        let key = format!("mf{i:05}");
        st.set(key.as_bytes(), &vec![b'v'; 500], 0, 0).unwrap();
    }
    // every 4th step makes no progress (still counts as active) — the
    // synchronous drain loop must absorb that and converge anyway
    let _fp = failpoint::armed("migrate.step.fail", "1in4").unwrap();
    let migs = st
        .reconfigure(ChunkSizePolicy::Explicit(vec![520, 620, 950]))
        .unwrap();
    assert!(failpoint::fire_count("migrate.step.fail") > 0);
    let moved: usize = migs.iter().map(|m| m.items_moved).sum();
    let dropped: usize = migs.iter().map(|m| m.items_dropped).sum();
    assert_eq!(moved + dropped, 3000);
    assert_eq!(dropped, 0);
    failpoint::disarm_all();
    st.check_integrity().unwrap();
}

#[test]
fn force_drain_failures_degrade_to_accounted_drops() {
    let _g = serial();
    // 64 KiB pages + undersized budget: the drain *needs* force-drains
    let st = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            64 << 10,
            4 << 20,
            true,
            1,
            Clock::System,
        )
        .unwrap(),
    );
    for i in 0..20_000 {
        match st.set(format!("fd{i:05}").as_bytes(), &vec![b'v'; 500], 0, 0) {
            Ok(()) | Err(StoreError::OutOfMemory) => {}
            Err(e) => panic!("set failed: {e}"),
        }
    }
    let live_before = st.len();
    let _fp = failpoint::armed("migrate.force_drain.fail", "1in3").unwrap();
    let migs = st
        .reconfigure(ChunkSizePolicy::Explicit(vec![520, 620, 950]))
        .unwrap();
    let moved: usize = migs.iter().map(|m| m.items_moved).sum();
    let dropped: usize = migs.iter().map(|m| m.items_dropped).sum();
    // refused reclaims may cost extra drops, never accounting
    assert_eq!(moved + dropped, live_before);
    assert_eq!(st.migration_gauges().dropped, dropped as u64);
    failpoint::disarm_all();
    st.check_integrity().unwrap();
}

#[test]
fn maintainer_pass_panic_storm_counts_restarts() {
    let _g = serial();
    let st = store(8 << 20, PAGE_SIZE, 1);
    st.set(b"k", b"v", 0, 0).unwrap();
    let before = supervisor::thread_restarts();
    let _fp = failpoint::armed("maintainer.pass.panic", "1in3").unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let maint = spawn_maintainer(
        st.clone(),
        MaintainerConfig {
            interval_ms: 1,
            ..MaintainerConfig::default()
        },
        stop.clone(),
    );
    assert!(
        wait_until(Duration::from_secs(20), || {
            supervisor::thread_restarts() >= before + 2
        }),
        "repeated panics must keep being survived, not kill the thread"
    );
    failpoint::disarm_all();
    stop.store(true, Ordering::SeqCst);
    maint.join().unwrap();
    assert_eq!(st.get(b"k").unwrap().value, b"v");
    st.check_integrity().unwrap();
}

#[test]
fn autotune_pass_panic_is_supervised_and_next_pass_runs() {
    let _g = serial();
    let st = store(32 << 20, PAGE_SIZE, 2);
    let collector = Arc::new(SizeCollector::default());
    st.set_observer(collector.clone());
    for i in 0..500 {
        let key = format!("at{i:04}");
        st.set(key.as_bytes(), &vec![b'v'; 500], 0, 0).unwrap();
    }
    let tuner = AutoTuner::new(
        st.clone(),
        collector,
        OptimizerSettings {
            enabled: true,
            interval_secs: 3600, // only kicked passes run in this test
            min_samples: 100,
            min_improvement: 2.0, // never auto-apply: panic is the subject
            ..OptimizerSettings::default()
        },
        PAGE_SIZE,
    )
    .unwrap();
    let before = supervisor::thread_restarts();
    let _fp = failpoint::armed("autotune.pass.panic", "once").unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let h = tuner.spawn(stop.clone());
    assert!(tuner.optimize_now().starts_with("OPTIMIZING"));
    assert!(
        wait_until(Duration::from_secs(20), || {
            supervisor::thread_restarts() > before
        }),
        "autotune panic must be supervised"
    );
    // the thread is alive again: a fresh kick completes a real pass
    assert!(tuner.optimize_now().starts_with("OPTIMIZING"));
    assert!(
        wait_until(Duration::from_secs(20), || tuner.optimize_gauges().runs >= 1),
        "post-restart pass never completed"
    );
    stop.store(true, Ordering::SeqCst);
    h.join().unwrap();
    st.check_integrity().unwrap();
}

/// Every 4th accept pretends the process is out of fds; the relief
/// path (reserve fd + reap) may sacrifice a connection, so clients
/// retry — what must hold is that service recovers every time.
fn emfile_storm(h: &ServerHandle, st: &Arc<ShardedStore>) {
    let _fp = failpoint::armed("accept.emfile", "1in4").unwrap();
    let mut ok = 0u32;
    for i in 0..30 {
        let done = (0..3).any(|_| {
            let Ok(mut c) = Client::connect(h.addr()) else {
                std::thread::sleep(Duration::from_millis(20));
                return false;
            };
            match c.set(&format!("em{i}"), b"v", 0, 0) {
                Ok(()) => true,
                Err(_) => {
                    std::thread::sleep(Duration::from_millis(20));
                    false
                }
            }
        });
        if done {
            ok += 1;
        }
    }
    assert!(failpoint::fire_count("accept.emfile") > 0);
    assert!(ok >= 25, "only {ok}/30 clients served under fd pressure");
    failpoint::disarm_all();
    let mut c = Client::connect(h.addr()).unwrap();
    c.set("after", b"ok", 0, 0).unwrap();
    st.check_integrity().unwrap();
}

#[test]
fn accept_emfile_relief_keeps_accepting() {
    let _g = serial();
    let st = store(16 << 20, PAGE_SIZE, 2);
    let h = server(&st);
    emfile_storm(&h, &st);
    h.shutdown();
}

/// The per-reactor relief path: each SO_REUSEPORT reactor owns its own
/// reserve fd and reaps its own idle slab when the fd limit bites.
#[cfg(target_os = "linux")]
#[test]
fn reuseport_reactor_emfile_relief_keeps_accepting() {
    let _g = serial();
    let st = store(16 << 20, PAGE_SIZE, 2);
    let h = Server::new(st.clone())
        .reactor_threads(2)
        .start("127.0.0.1:0")
        .unwrap();
    assert!(h.reuseport(), "event mode must default to reuseport");
    emfile_storm(&h, &st);
    h.shutdown();
}

/// The single-listener fallback (accept thread) keeps its own relief.
#[test]
fn fallback_accept_thread_emfile_relief_keeps_accepting() {
    let _g = serial();
    let st = store(16 << 20, PAGE_SIZE, 2);
    let h = Server::new(st.clone())
        .reuseport(false)
        .start("127.0.0.1:0")
        .unwrap();
    assert!(!h.reuseport());
    emfile_storm(&h, &st);
    h.shutdown();
}

// ------------------------------------------------- randomized schedule

/// Failpoints that are safe under an arbitrary `1inN` schedule (no
/// blocking `pause`, no test-thread panics, no spin-prone retries).
const RANDOM_SAFE: &[&str] = &[
    "store.item_alloc",
    "slab.page_alloc",
    "sys.writev.eagain",
    "sys.writev.short",
    "conn.read.eintr",
    "migrate.step.fail",
    "migrate.force_drain.fail",
    "accept.emfile",
];

fn chaos_seed() -> u64 {
    let env = std::env::var("SLABFORGE_CHAOS_SEED").ok();
    env.and_then(|s| s.parse().ok()).unwrap_or(0x5EED_C4A0)
}

#[test]
fn randomized_schedule_no_aborts_no_corruption() {
    let _g = serial();
    let seed = chaos_seed();
    // captured output surfaces on failure: rerun with
    // SLABFORGE_CHAOS_SEED=<seed> to reproduce
    eprintln!("chaos: SLABFORGE_CHAOS_SEED={seed}");
    let mut rng = Pcg64::new(seed);

    let st = store(16 << 20, PAGE_SIZE, 2);
    let h = server(&st);
    // arm 4 random points at random 1inN rates
    let mut picks: Vec<&'static str> = Vec::new();
    while picks.len() < 4 {
        let p = RANDOM_SAFE[rng.gen_range(RANDOM_SAFE.len() as u64) as usize];
        if !picks.contains(&p) {
            picks.push(p);
        }
    }
    let spec: Vec<String> = picks
        .iter()
        .map(|p| format!("{p}=1in{}", rng.gen_range_inclusive(3, 31)))
        .collect();
    let spec = spec.join(",");
    eprintln!("chaos: schedule {spec}");
    failpoint::arm_list(&spec).unwrap();

    let mut c = Client::connect(h.addr()).ok();
    for op in 0..600 {
        if op == 300 {
            // live reconfigure mid-storm (step/force-drain faults may
            // be armed — the drain loop must still converge)
            st.reconfigure(ChunkSizePolicy::Explicit(vec![300, 640, 1300]))
                .unwrap();
        }
        let k = rng.gen_range(200);
        let key = format!("rz{k:03}");
        let fill = b'a' + (k % 26) as u8;
        let Some(cl) = c.as_mut() else {
            c = Client::connect(h.addr()).ok();
            continue;
        };
        let res = if rng.gen_range(2) == 0 {
            let len = 16 + rng.gen_range(1200) as usize;
            cl.set(&key, &vec![fill; len], 0, 0).map(|_| ())
        } else {
            match cl.get(&key) {
                // a hit must be intact: right fill byte, whole length
                Ok(Some(v)) => {
                    assert!(
                        v.value.iter().all(|&b| b == fill),
                        "seed {seed}: corrupt value for {key}"
                    );
                    Ok(())
                }
                Ok(None) => Ok(()),
                Err(e) => Err(e),
            }
        };
        if res.is_err() {
            // injected fault surfaced as an error or clean close —
            // fine; reconnect and carry on
            c = Client::connect(h.addr()).ok();
        }
    }
    failpoint::disarm_all();
    // calm after the storm: full service, intact store
    let mut c = Client::connect(h.addr()).unwrap();
    c.set("calm", b"after-storm", 0, 0).unwrap();
    assert_eq!(c.get("calm").unwrap().unwrap().value, b"after-storm");
    st.check_integrity().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
    h.shutdown();
}

// ------------------------------------------- overload shedding / drain

/// Pipeline `n` gets of `key` and never read: the kernel buffers fill,
/// the reactor's pending-output grows, and the conn counts against the
/// global buffer budget.
fn stalled_reader(addr: std::net::SocketAddr, key: &str, n: usize) -> TcpStream {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let req = format!("get {key}\r\n").repeat(n);
    s.write_all(req.as_bytes()).unwrap();
    s
}

#[test]
fn buffer_budget_sheds_stalled_readers_not_healthy_conns() {
    let _g = serial();
    let st = store(64 << 20, PAGE_SIZE, 2);
    let budget = 128 << 10;
    let h = Server::new(st.clone())
        .conn_buffer_budget(budget)
        .start("127.0.0.1:0")
        .unwrap();
    // healthy conn established before the storm (accepts pause while
    // the gauge is over budget, so connecting later could block).
    // 64 KiB value: under the budget, so the healthy conn's own
    // responses can never make it a shedding candidate
    let mut healthy = Client::connect(h.addr()).unwrap();
    healthy.set("big", &vec![b'B'; 64 << 10], 0, 0).unwrap();

    // 3 stalled readers × 400 × 64 KiB demanded ≫ kernel buffering:
    // pending output must accumulate far past the 128 KiB budget
    let mk = |_| stalled_reader(h.addr(), "big", 400);
    let stalled: Vec<TcpStream> = (0..3).map(mk).collect();

    assert!(
        wait_until(Duration::from_secs(15), || {
            h.metrics.shed_connections.load(Ordering::Relaxed) > 0
        }),
        "over-budget stalled readers were never shed"
    );
    // shedding must bring the gauge back under budget (all pending
    // output belonged to the stalled conns)
    assert!(
        wait_until(Duration::from_secs(15), || {
            h.metrics.conn_buffer_bytes.load(Ordering::Relaxed) <= budget as u64
        }),
        "gauge stuck over budget after shedding"
    );
    // the healthy connection was never the victim: it still serves
    healthy.set("alive", b"yes", 0, 0).unwrap();
    assert_eq!(healthy.get("alive").unwrap().unwrap().value, b"yes");
    assert_eq!(healthy.get("big").unwrap().unwrap().value.len(), 64 << 10);
    drop(stalled);
    st.check_integrity().unwrap();
    h.shutdown();
}

#[test]
fn shutdown_drains_within_bound_under_pathological_clients() {
    let _g = serial();
    let st = store(64 << 20, PAGE_SIZE, 2);
    let h = server(&st);
    let mut c = Client::connect(h.addr()).unwrap();
    c.set("big", &vec![b'B'; 400 << 10], 0, 0).unwrap();
    drop(c);

    // pathological client #1: megabytes of pending responses, never reads
    let mut stalled = stalled_reader(h.addr(), "big", 40);
    // pathological client #2: cut off mid `ms` data block — the server
    // is parked waiting for 100 KB that will never arrive
    let mut partial = TcpStream::connect(h.addr()).unwrap();
    partial.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    partial.write_all(b"ms part 100000\r\n").unwrap();
    partial.write_all(&vec![b'x'; 10_000]).unwrap();
    std::thread::sleep(Duration::from_millis(150)); // let the server ingest

    // the drain deadline (not the slowest client) bounds shutdown
    let max_ms: u64 = std::env::var("SLABFORGE_TEST_MAX_SHUTDOWN_MS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8_000);
    let t0 = Instant::now();
    h.shutdown();
    let took = t0.elapsed();
    assert!(
        took <= Duration::from_millis(max_ms),
        "shutdown took {took:?} with stalled clients (bound {max_ms} ms)"
    );

    // both sockets observe a real close (drain what was in flight,
    // then EOF / reset — never an indefinite hang)
    for (name, s) in [("stalled", &mut stalled), ("partial", &mut partial)] {
        let mut buf = [0u8; 64 << 10];
        let mut eof = false;
        for _ in 0..400 {
            match s.read(&mut buf) {
                Ok(0) | Err(_) => {
                    eof = true;
                    break;
                }
                Ok(_) => {} // draining buffered responses
            }
        }
        assert!(eof, "{name} socket never saw the close");
    }
    // the half-received `ms` upload must not have landed
    assert!(st.get(b"part").is_none(), "partial upload must be dropped");
    st.check_integrity().unwrap();
}

// ------------------------------------------------ warm-restart chaos
//
// These drive the real binary end-to-end: boot with `--memory-file`,
// talk the memcached protocol over TCP, deliver real signals, and
// assert on the next boot's `restart_*` stats. Each test owns a unique
// temp directory and its own server processes, so — unlike the
// failpoint schedules above — they need no [`serial`] guard.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

struct ServerProc {
    child: Child,
    addr: std::net::SocketAddr,
    /// Startup stderr up to (and including) the listening line — the
    /// `restart:` banner lives here.
    banner: Vec<String>,
}

fn restart_dir(test: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "slabforge-chaos-restart-{test}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spawn_server(memfile: &Path, extra_args: &[&str], envs: &[(&str, &str)]) -> ServerProc {
    use std::io::BufRead;
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_slabforge"));
    cmd.args([
        "serve",
        "--listen",
        "127.0.0.1:0",
        "--mem-limit",
        "8388608",
        "--shards",
        "2",
        "--memory-file",
        memfile.to_str().unwrap(),
    ])
    .args(extra_args)
    .stdin(Stdio::null())
    .stdout(Stdio::null())
    .stderr(Stdio::piped());
    for (k, v) in envs {
        cmd.env(k, v);
    }
    let mut child = cmd.spawn().unwrap();
    let mut lines = std::io::BufReader::new(child.stderr.take().unwrap());
    let mut banner = Vec::new();
    let addr = loop {
        let mut line = String::new();
        if lines.read_line(&mut line).unwrap() == 0 {
            let status = child.wait().unwrap();
            panic!("server exited ({status}) before listening; stderr: {banner:#?}");
        }
        let line = line.trim_end().to_string();
        let listening = line.strip_prefix("slabforge listening on ").map(|rest| {
            rest.split_whitespace()
                .next()
                .unwrap()
                .parse::<std::net::SocketAddr>()
                .unwrap()
        });
        banner.push(line);
        if let Some(addr) = listening {
            break addr;
        }
    };
    // keep draining so shutdown logging can never block the child on a
    // full pipe; the assertions below use exit codes + the next boot
    std::thread::spawn(move || {
        let mut line = String::new();
        while matches!(lines.read_line(&mut line), Ok(n) if n > 0) {
            line.clear();
        }
    });
    ServerProc { child, addr, banner }
}

impl ServerProc {
    fn client(&self) -> Client {
        for _ in 0..200 {
            if let Ok(c) = Client::connect(self.addr) {
                return c;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("cannot connect to {}", self.addr);
    }

    fn booted(&self, how: &str) -> bool {
        let prefix = format!("restart: {how}");
        self.banner.iter().any(|l| l.starts_with(prefix.as_str()))
    }

    fn sigterm(&self) {
        let st = Command::new("kill")
            .args(["-TERM", &self.child.id().to_string()])
            .status()
            .unwrap();
        assert!(st.success(), "kill -TERM failed");
    }

    /// Bounded wait for exit; SIGKILLs and panics past the deadline.
    fn wait_exit(mut self) -> i32 {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            if let Some(st) = self.child.try_wait().unwrap() {
                return st.code().unwrap_or(-1);
            }
            if Instant::now() > deadline {
                let _ = self.child.kill();
                panic!("server did not exit within 30s");
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    /// kill-9: no drain, no manifest — the dirty marker stays behind.
    fn kill9(mut self) {
        self.child.kill().unwrap();
        let _ = self.child.wait();
    }
}

#[test]
fn warm_restart_roundtrip_over_tcp() {
    let dir = restart_dir("roundtrip");
    let mem = dir.join("cache.mem");

    // boot 1: explicit ("learned") geometry + a tenant, all via flags
    let s1 = spawn_server(
        &mem,
        &["--slab-sizes", "200,333,480,1024,65536", "--tenants", "acme=acme"],
        &[],
    );
    assert!(s1.booted("cold"), "fresh file must boot cold: {:?}", s1.banner);
    let mut c = s1.client();
    let mut want: Vec<(String, Vec<u8>)> = Vec::new();
    for i in 0..200u32 {
        // two keys land in the acme namespace; values cover every byte
        let key = if i < 2 { format!("acme:k{i:03}") } else { format!("k{i:03}") };
        let len = (17 + i as usize * 7) % 700 + 1;
        let val: Vec<u8> = (0..len).map(|j| ((i as usize + j) % 256) as u8).collect();
        c.set(&key, &val, i, 0).unwrap();
        want.push((key, val));
    }
    let cas1 = c.gets("k010").unwrap().unwrap().cas.unwrap();
    drop(c);
    s1.sigterm();
    assert_eq!(s1.wait_exit(), 0, "clean shutdown must persist the manifest");

    // boot 2: NO --slab-sizes, NO --tenants — geometry, tenant rules,
    // and every byte must come back from the memory file + manifest
    let s2 = spawn_server(&mem, &[], &[]);
    assert!(s2.booted("warm"), "{:?}", s2.banner);
    let mut c = s2.client();
    let stats = c.stats(None).unwrap();
    assert_eq!(stats["restart_state"], "warm");
    assert_eq!(stats["restart_items_recovered"], "200");
    assert_eq!(stats["restart_items_discarded"], "0");
    assert!(stats.contains_key("restart_duration_ms"), "{stats:?}");
    for (key, val) in &want {
        let got = c.get(key).unwrap().unwrap_or_else(|| panic!("{key} lost across restart"));
        assert_eq!(&got.value, val, "{key} corrupted across restart");
    }
    // flags are part of the manifest
    assert_eq!(c.get("k010").unwrap().unwrap().flags, 10);
    // per-key CAS monotonicity across the restart
    c.set("k010", b"overwritten", 0, 0).unwrap();
    let cas2 = c.gets("k010").unwrap().unwrap().cas.unwrap();
    assert!(cas2 > cas1, "CAS regressed across restart: {cas1} -> {cas2}");
    // learned geometry came back: a fresh ~250 B value lands in the 333
    // class that only the persisted explicit policy has
    c.set("geom", &vec![b'g'; 250], 0, 0).unwrap();
    let slabs = c.stats(Some("slabs")).unwrap();
    assert!(
        slabs.iter().any(|(k, v)| k.ends_with(":chunk_size") && v == "333"),
        "persisted geometry missing from stats slabs: {slabs:?}"
    );
    // tenant registry restored without --tenants
    let tenants = c.stats(Some("tenants")).unwrap();
    assert!(
        tenants.iter().any(|(k, v)| k.ends_with(":name") && v == "acme"),
        "tenant registry not restored: {tenants:?}"
    );
    drop(c);
    s2.sigterm();
    assert_eq!(s2.wait_exit(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_nine_forces_cold_restart() {
    let dir = restart_dir("kill9");
    let mem = dir.join("cache.mem");
    let s1 = spawn_server(&mem, &[], &[]);
    let mut c = s1.client();
    c.set("doomed", b"value", 0, 0).unwrap();
    drop(c);
    s1.kill9();
    let s2 = spawn_server(&mem, &[], &[]);
    assert!(s2.booted("cold"), "{:?}", s2.banner);
    let mut c = s2.client();
    let stats = c.stats(None).unwrap();
    assert_eq!(stats["restart_state"], "cold");
    assert!(stats["restart_reason"].contains("dirty"), "{stats:?}");
    assert_eq!(stats["restart_items_recovered"], "0");
    assert!(
        c.get("doomed").unwrap().is_none(),
        "a crashed run's data must never be served"
    );
    drop(c);
    s2.sigterm();
    assert_eq!(s2.wait_exit(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_corruption_and_geometry_mismatch_force_cold() {
    let dir = restart_dir("invalidate");
    let mem = dir.join("cache.mem");
    let meta = {
        let mut m = mem.clone().into_os_string();
        m.push(".meta");
        PathBuf::from(m)
    };
    let cycle = |args: &[&str]| {
        let s = spawn_server(&mem, args, &[]);
        let mut c = s.client();
        c.set("k", b"v", 0, 0).unwrap();
        drop(c);
        s.sigterm();
        assert_eq!(s.wait_exit(), 0);
    };

    // flip one manifest body byte: checksum must reject it
    cycle(&[]);
    let mut raw = std::fs::read(&meta).unwrap();
    let last = raw.len() - 1;
    raw[last] ^= 0xFF;
    std::fs::write(&meta, &raw).unwrap();
    let s = spawn_server(&mem, &[], &[]);
    assert!(s.booted("cold"), "{:?}", s.banner);
    let mut c = s.client();
    let stats = c.stats(None).unwrap();
    assert_eq!(stats["restart_state"], "cold");
    assert!(stats["restart_reason"].contains("checksum"), "{stats:?}");
    assert!(c.get("k").unwrap().is_none());
    drop(c);
    s.sigterm();
    assert_eq!(s.wait_exit(), 0);

    // shard count changed between runs: geometry check must refuse
    cycle(&[]);
    let s = spawn_server(&mem, &["--shards", "4"], &[]);
    assert!(s.booted("cold"), "{:?}", s.banner);
    let mut c = s.client();
    let stats = c.stats(None).unwrap();
    assert_eq!(stats["restart_state"], "cold");
    assert!(stats["restart_reason"].contains("shard count"), "{stats:?}");
    drop(c);
    s.sigterm();
    assert_eq!(s.wait_exit(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_write_failure_in_subprocess_degrades_next_boot_to_cold() {
    let dir = restart_dir("fp-write");
    let mem = dir.join("cache.mem");
    // the failpoint rides the documented env var into the subprocess
    let s1 = spawn_server(
        &mem,
        &[],
        &[("SLABFORGE_FAILPOINTS", "restart.manifest.write_fail=always")],
    );
    let mut c = s1.client();
    c.set("k", b"v", 0, 0).unwrap();
    drop(c);
    s1.sigterm();
    assert_eq!(s1.wait_exit(), 1, "failed manifest write must exit nonzero");
    let s2 = spawn_server(&mem, &[], &[]);
    assert!(s2.booted("cold"), "{:?}", s2.banner);
    let mut c = s2.client();
    let stats = c.stats(None).unwrap();
    assert_eq!(stats["restart_state"], "cold");
    assert!(stats["restart_reason"].contains("dirty"), "{stats:?}");
    assert!(c.get("k").unwrap().is_none());
    drop(c);
    s2.sigterm();
    assert_eq!(s2.wait_exit(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}
