//! Property-based invariant tests over the core subsystems, using the
//! in-house `testutil` harness (proptest is not vendored offline).
//! Each property runs many seeded random cases; failures print the
//! reproducing seed.

use slabforge::optimizer::dp::{brute_force_optimal, dp_optimal};
use slabforge::optimizer::hillclimb::{paper_hill_climb, HillClimbParams};
use slabforge::optimizer::steepest::{steepest_descent, SteepestParams};
use slabforge::optimizer::engine::{RustBackend, WasteBackend};
use slabforge::optimizer::waste::WasteMap;
use slabforge::protocol::parse::parse_command;
use slabforge::protocol::request::{want, Opcode};
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::{SlabAllocator, SlabError};
use slabforge::store::store::{Clock, KvStore};
use slabforge::testutil::{check, gen};
use slabforge::util::rng::Pcg64;
use std::collections::HashMap;

// ---------------------------------------------------------------- allocator

#[test]
fn prop_allocator_accounting_balances() {
    check("allocator accounting", 40, |rng| {
        let mut a = SlabAllocator::new(&ChunkSizePolicy::default(), 1 << 20, 32 << 20).unwrap();
        let mut live: Vec<(slabforge::slab::ChunkHandle, usize)> = Vec::new();
        let mut requested = 0u64;
        for _ in 0..500 {
            if live.is_empty() || rng.chance(0.6) {
                let size = 50 + rng.gen_range(8000) as usize;
                match a.alloc(size) {
                    Ok(h) => {
                        live.push((h, size));
                        requested += size as u64;
                    }
                    Err(SlabError::NeedEviction { .. }) => {}
                    Err(e) => panic!("unexpected {e}"),
                }
            } else {
                let i = rng.gen_range(live.len() as u64) as usize;
                let (h, size) = live.swap_remove(i);
                a.free(h, size);
                requested -= size as u64;
            }
        }
        let st = a.stats();
        assert_eq!(st.requested_bytes, requested, "requested mismatch");
        let used: usize = st.per_class.iter().map(|c| c.used_chunks).sum();
        assert_eq!(used, live.len(), "live chunk count mismatch");
        assert_eq!(
            st.allocated_bytes - st.requested_bytes,
            st.hole_bytes,
            "hole identity"
        );
        // every live handle's chunk covers its item
        for (h, size) in &live {
            assert!(a.chunk_size_of(h.class) >= *size);
        }
    });
}

#[test]
fn prop_class_selection_is_smallest_covering() {
    check("class selection", 30, |rng| {
        let n = 2 + rng.gen_range(20) as usize;
        let sizes = gen::ascending_sizes(rng, n, 96, 500_000)
            .into_iter()
            .map(|s| (s as usize + 7) & !7)
            .collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect::<Vec<_>>();
        let a = SlabAllocator::new(
            &ChunkSizePolicy::Explicit(sizes.clone()),
            1 << 20,
            1 << 20,
        )
        .unwrap();
        for _ in 0..100 {
            let want = 1 + rng.gen_range(1 << 20) as usize;
            match a.class_for_size(want) {
                Some(class) => {
                    let chunk = a.chunk_size_of(class);
                    assert!(chunk >= want);
                    // no smaller class also covers it
                    if class > 0 {
                        assert!(a.chunk_size_of(class - 1) < want);
                    }
                }
                None => assert!(want > a.max_item_size()),
            }
        }
    });
}

// ------------------------------------------------------------------- waste

#[test]
fn prop_waste_fast_path_matches_naive() {
    check("waste fast == naive", 60, |rng| {
        let n = 1 + rng.gen_range(100) as usize;
        let pairs = gen::histogram_pairs(rng, n, 20_000, 10_000);
        let map = WasteMap::from_pairs(pairs.iter().copied());
        let k = 1 + rng.gen_range(10) as usize;
        let cfg: Vec<u32> = (0..k).map(|_| 1 + rng.gen_range(25_000) as u32).collect();
        assert_eq!(map.waste_of(&cfg), map.waste_of_naive(&cfg));
    });
}

#[test]
fn prop_waste_monotone_in_classes() {
    check("adding a class never hurts", 40, |rng| {
        let pairs = gen::histogram_pairs(rng, 50, 10_000, 1000);
        let map = WasteMap::from_pairs(pairs.iter().copied());
        let cfg: Vec<u32> = (0..4).map(|_| 1 + rng.gen_range(12_000) as u32).collect();
        let mut more = cfg.clone();
        more.push(1 + rng.gen_range(12_000) as u32);
        assert!(map.waste_of(&more) <= map.waste_of(&cfg));
    });
}

// ---------------------------------------------------------------- optimizer

#[test]
fn prop_dp_matches_brute_force() {
    check("dp == brute force", 25, |rng| {
        let n = 3 + rng.gen_range(8) as usize;
        let pairs = gen::histogram_pairs(rng, n, 3000, 100);
        let map = WasteMap::from_pairs(pairs.iter().copied());
        let k = 1 + rng.gen_range(n.min(4) as u64) as usize;
        let dp = dp_optimal(&map, k);
        let (_, bf_waste) = brute_force_optimal(&map, k);
        assert_eq!(dp.waste, bf_waste, "k={k} pairs={pairs:?}");
    });
}

#[test]
fn prop_greedy_never_below_dp_bound() {
    check("dp <= greedy", 15, |rng| {
        let pairs = gen::histogram_pairs(rng, 60, 8000, 500);
        let map = WasteMap::from_pairs(pairs.iter().copied());
        let backend = RustBackend::new(WasteMap::from_pairs(pairs.iter().copied()));
        let max = pairs.iter().map(|&(s, _)| s).max().unwrap();
        let full = vec![96u32, max / 2, max, max + 500];
        let span = 0..3usize;

        let dp = dp_optimal(&map, 4).waste; // 4 free classes >= greedy's 3+suffix
        let hc = paper_hill_climb(
            &backend,
            &full,
            span.clone(),
            &HillClimbParams {
                max_failures: 200,
                ..Default::default()
            },
        );
        let st = steepest_descent(&backend, &full, span, &SteepestParams::default());
        assert!(dp <= backend.eval_one(&hc.config), "dp bound vs hillclimb");
        assert!(dp <= backend.eval_one(&st.config), "dp bound vs steepest");
    });
}

#[test]
fn prop_optimizer_outputs_valid_ascending_configs() {
    check("optimizer output validity", 20, |rng| {
        let pairs = gen::histogram_pairs(rng, 40, 5000, 300);
        let backend = RustBackend::new(WasteMap::from_pairs(pairs.iter().copied()));
        let full: Vec<u32> = slabforge::slab::geometry::memcached_default_sizes()
            .iter()
            .map(|&c| c as u32)
            .collect();
        let hi = full.len().min(12);
        let out = steepest_descent(&backend, &full, 2..hi, &SteepestParams::default());
        assert!(
            out.config.windows(2).all(|w| w[0] < w[1]),
            "not ascending: {:?}",
            out.config
        );
        assert_eq!(out.config.len(), full.len());
    });
}

// ------------------------------------------------------------------- store

#[test]
fn prop_store_matches_model_hashmap() {
    check("store == model", 12, |rng| {
        let mut store = KvStore::new(
            ChunkSizePolicy::default(),
            1 << 20,
            64 << 20,
            true,
            Clock::System,
        )
        .unwrap();
        let mut model: HashMap<Vec<u8>, Vec<u8>> = HashMap::new();
        for _ in 0..400 {
            let key = gen::key(rng, 12);
            match rng.gen_range(4) {
                0 | 1 => {
                    let vlen = rng.gen_range(2000) as usize;
                    let mut value = vec![0u8; vlen];
                    for b in value.iter_mut() {
                        *b = rng.gen_range(256) as u8;
                    }
                    store.set(&key, &value, 0, 0).unwrap();
                    model.insert(key, value);
                }
                2 => {
                    let got = store.get(&key).map(|v| v.value);
                    assert_eq!(got, model.get(&key).cloned(), "get {key:?}");
                }
                _ => {
                    let was = store.delete(&key);
                    assert_eq!(was, model.remove(&key).is_some(), "delete {key:?}");
                }
            }
        }
        assert_eq!(store.len(), model.len());
        // final sweep
        for (k, v) in &model {
            assert_eq!(store.get(k).unwrap().value, *v);
        }
    });
}

#[test]
fn prop_maintainer_preserves_lru_invariants() {
    // Randomized interleavings of inserts/gets/deletes with bounded
    // maintainer steps: at every step no id may be lost or linked
    // twice across the LRU tiers and the hole identity must hold; once
    // the maintainer settles, the HOT/WARM fraction caps must hold too.
    check("maintainer invariants", 10, |rng| {
        let mut store = KvStore::new(
            ChunkSizePolicy::default(),
            1 << 20,
            64 << 20,
            true,
            Clock::System,
        )
        .unwrap();
        let mut live: Vec<Vec<u8>> = Vec::new();
        for step in 0..600 {
            match rng.gen_range(10) {
                // 60% inserts (various sizes → several classes)
                0..=5 => {
                    let key = gen::key(rng, 14);
                    let vlen = 1 + rng.gen_range(4000) as usize;
                    store.set(&key, &vec![b'v'; vlen], 0, 0).unwrap();
                    live.push(key);
                }
                // 20% gets (touch → promotion churn)
                6 | 7 => {
                    if !live.is_empty() {
                        let i = rng.gen_range(live.len() as u64) as usize;
                        store.get(&live[i]);
                    }
                }
                // 10% deletes
                8 => {
                    if !live.is_empty() {
                        let i = rng.gen_range(live.len() as u64) as usize;
                        let key = live.swap_remove(i);
                        store.delete(&key);
                        // a re-set key may appear twice in the list
                        live.retain(|k| k != &key);
                    }
                }
                // 10% bounded maintainer steps
                _ => {
                    store.maintain(1 + rng.gen_range(64) as usize);
                }
            }
            if step % 50 == 0 {
                store.check_integrity().unwrap_or_else(|e| panic!("step {step}: {e}"));
            }
        }
        store.check_integrity().unwrap();
        // settle: a full maintenance pass must restore every cap
        while store.maintain(usize::MAX).0 > 0 {}
        assert!(store.lru_balanced(), "caps must hold after settling");
        store.check_integrity().unwrap();
        // per-class caps concretely: hot <= max(20%,1), warm <= max(40%,1)
        for (hot, warm, cold) in store.lru_tier_sizes() {
            let total = hot + warm + cold;
            if total == 0 {
                continue;
            }
            assert!(hot <= (total * 20 / 100).max(1), "hot {hot} of {total}");
            assert!(warm <= (total * 40 / 100).max(1), "warm {warm} of {total}");
        }
        // nothing was lost: every surviving key still reads back
        for key in &live {
            assert!(store.get(key).is_some(), "lost {key:?}");
        }
    });
}

#[test]
fn prop_reconfigure_preserves_model() {
    check("reconfigure preserves data", 8, |rng| {
        let mut store = KvStore::new(
            ChunkSizePolicy::default(),
            1 << 20,
            64 << 20,
            true,
            Clock::System,
        )
        .unwrap();
        let mut model: HashMap<Vec<u8>, usize> = HashMap::new();
        for i in 0..300u32 {
            let key = format!("key-{i}").into_bytes();
            let vlen = 1 + rng.gen_range(3000) as usize;
            store.set(&key, &vec![b'p'; vlen], 0, 0).unwrap();
            model.insert(key, vlen);
        }
        // random (valid) new config
        let sizes = gen::ascending_sizes(rng, 5, 96, 8000)
            .into_iter()
            .map(|s| s as usize)
            .collect::<Vec<_>>();
        let report = store.reconfigure(ChunkSizePolicy::Explicit(sizes)).unwrap();
        assert_eq!(report.items_dropped, 0, "64 MiB is plenty");
        for (k, vlen) in &model {
            assert_eq!(store.get(k).unwrap().value.len(), *vlen);
        }
    });
}

// ---------------------------------------------------------------- protocol

#[test]
fn prop_parser_never_panics_on_garbage() {
    check("parser total", 50, |rng| {
        let len = rng.gen_range(200) as usize;
        let mut line: Vec<u8> = Vec::with_capacity(len + 3);
        // bias toward the meta verbs so both front-ends get fuzzed
        match rng.gen_range(8) {
            0 => line.extend_from_slice(b"mg "),
            1 => line.extend_from_slice(b"ms "),
            2 => line.extend_from_slice(b"md "),
            3 => line.extend_from_slice(b"ma "),
            _ => {}
        }
        for _ in 0..len {
            line.push(match rng.gen_range(4) {
                0 => b' ',
                1 => rng.gen_range(256) as u8,
                _ => 33 + rng.gen_range(94) as u8,
            });
        }
        let _ = parse_command(&line); // must not panic
    });
}

#[test]
fn prop_conn_never_panics_on_malformed_streams() {
    use slabforge::server::{Conn, NoControl};
    use slabforge::slab::PAGE_SIZE;
    use slabforge::store::sharded::ShardedStore;
    use std::sync::Arc;
    check("conn total", 12, |rng| {
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                PAGE_SIZE,
                8 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        let mut c = Conn::new(store, Arc::new(NoControl));
        let mut out = Vec::new();
        // a stream of mostly-broken classic + meta lines, some with
        // data blocks, fed in random fragment sizes — the state
        // machine must neither panic nor wedge
        let verbs: [&[u8]; 10] = [
            b"get", b"set", b"mg", b"ms", b"md", b"ma", b"mn", b"gat", b"stats", b"bogus",
        ];
        let mut stream = Vec::new();
        for _ in 0..30 {
            stream.extend_from_slice(verbs[rng.gen_range(10) as usize]);
            let toks = rng.gen_range(4);
            for _ in 0..toks {
                stream.push(b' ');
                let tok_len = 1 + rng.gen_range(8) as usize;
                for _ in 0..tok_len {
                    stream.push(33 + rng.gen_range(94) as u8);
                }
            }
            stream.extend_from_slice(b"\r\n");
            if rng.chance(0.3) {
                // sometimes a stray data-ish blob
                let blob = rng.gen_range(20) as usize;
                for _ in 0..blob {
                    stream.push(rng.gen_range(256) as u8);
                }
                stream.extend_from_slice(b"\r\n");
            }
        }
        let mut fed = 0;
        while fed < stream.len() {
            let take = (1 + rng.gen_range(64) as usize).min(stream.len() - fed);
            c.on_bytes(&stream[fed..fed + take], &mut out);
            fed += take;
        }
    });
}

#[test]
fn prop_parser_roundtrips_valid_set_lines() {
    check("parser roundtrip", 30, |rng| {
        let key = String::from_utf8(gen::key(rng, 30)).unwrap();
        let flags = rng.gen_range(1 << 16) as u32;
        let exp = rng.gen_range(1000) as u32;
        let n = rng.gen_range(10_000) as usize;
        let line = format!("set {key} {flags} {exp} {n}");
        let r = parse_command(line.as_bytes()).unwrap();
        assert_eq!(r.op, Opcode::Store);
        assert_eq!(r.key, key.as_bytes());
        assert_eq!(r.set_flags, flags);
        assert_eq!(r.exptime, exp);
        assert_eq!(r.nbytes, Some(n));
        assert_eq!(r.cas_compare, None);
    });
}

#[test]
fn prop_meta_flags_roundtrip_any_order() {
    check("meta flag roundtrip", 40, |rng| {
        let key = String::from_utf8(gen::key(rng, 30)).unwrap();
        let mut flags: Vec<String> = Vec::new();
        let mut expect_want = 0u16;
        for (tok, w) in [
            ("v", want::VALUE),
            ("f", want::FLAGS),
            ("c", want::CAS),
            ("t", want::TTL),
            ("s", want::SIZE),
            ("k", want::KEY),
        ] {
            if rng.chance(0.5) {
                flags.push(tok.to_string());
                expect_want |= w;
            }
        }
        let quiet = rng.chance(0.5);
        if quiet {
            flags.push("q".into());
        }
        let opaque = if rng.chance(0.5) {
            let o = format!("o{}", rng.gen_range(100_000));
            flags.push(format!("O{o}"));
            expect_want |= want::OPAQUE;
            Some(o)
        } else {
            None
        };
        let touch = if rng.chance(0.5) {
            let t = rng.gen_range(100_000) as u32;
            flags.push(format!("T{t}"));
            Some(t)
        } else {
            None
        };
        let vivify = if rng.chance(0.5) {
            let n = rng.gen_range(100_000) as u32;
            flags.push(format!("N{n}"));
            Some(n)
        } else {
            None
        };
        // shuffle the flag order (Fisher-Yates): order must not matter
        for i in (1..flags.len()).rev() {
            let j = rng.gen_range(i as u64 + 1) as usize;
            flags.swap(i, j);
        }
        let line = format!("mg {key} {}", flags.join(" "));
        let r = parse_command(line.as_bytes()).unwrap();
        assert_eq!(r.op, Opcode::Get);
        assert_eq!(r.key, key.as_bytes());
        assert_eq!(r.want, expect_want, "line: {line}");
        assert_eq!(r.quiet, quiet);
        assert_eq!(r.opaque, opaque.as_deref().unwrap_or("").as_bytes());
        assert_eq!(r.touch_ttl, touch);
        assert_eq!(r.vivify, vivify);
    });
}

#[test]
fn prop_meta_ms_tokens_roundtrip() {
    check("meta ms roundtrip", 30, |rng| {
        let key = String::from_utf8(gen::key(rng, 30)).unwrap();
        let n = rng.gen_range(10_000) as usize;
        let f = rng.gen_range(1 << 16) as u32;
        let t = rng.gen_range(100_000) as u32;
        let cc = rng.gen_range(u32::MAX as u64);
        let cs = rng.gen_range(u32::MAX as u64);
        let line = format!("ms {key} {n} F{f} T{t} C{cc} E{cs} c k q");
        let r = parse_command(line.as_bytes()).unwrap();
        assert_eq!(r.op, Opcode::Store);
        assert_eq!(r.nbytes, Some(n));
        assert_eq!(r.set_flags, f);
        assert_eq!(r.exptime, t, "T maps to the item TTL on ms");
        assert_eq!(r.cas_compare, Some(cc));
        assert_eq!(r.cas_set, Some(cs));
        assert_eq!(r.want, want::CAS | want::KEY);
        assert!(r.quiet);
    });
}

// ----------------------------------------------------------------- tenants

#[test]
fn prop_tenant_attribution_matches_model() {
    use slabforge::tenant::TenantRegistry;
    check("tenant attribution", 30, |rng| {
        let reg = TenantRegistry::new(1 << 20);
        // random rules over a tiny alphabet so prefixes nest and shadow
        let mut model: Vec<(Vec<u8>, u8)> = Vec::new();
        let n = 1 + rng.gen_range(6) as usize;
        for i in 0..n {
            let plen = 1 + rng.gen_range(4) as usize;
            let p: Vec<u8> = (0..plen).map(|_| b'a' + rng.gen_range(3) as u8).collect();
            let id = reg.define(&format!("t{i}"), &p, None).unwrap();
            model.retain(|(q, _)| q != &p);
            model.push((p, id));
        }
        let tok: Vec<u8> = (0..6).map(|_| b'A' + rng.gen_range(26) as u8).collect();
        let tid = reg.set_token("t1", &tok).unwrap();
        for _ in 0..200 {
            let klen = rng.gen_range(8) as usize;
            let k: Vec<u8> = (0..klen).map(|_| b'a' + rng.gen_range(3) as u8).collect();
            // an exact opaque-token match outranks any prefix
            assert_eq!(reg.attribute(&k, &tok), tid, "token must win");
            // otherwise: longest matching prefix, else the default
            // (equal-length matching prefixes are impossible — `define`
            // deduplicates — so the model is unambiguous)
            let expect = model
                .iter()
                .filter(|(p, _)| k.starts_with(p))
                .max_by_key(|(p, _)| p.len())
                .map_or(0, |(_, id)| *id);
            assert_eq!(reg.attribute(&k, b""), expect, "key {k:?}");
            // an unknown token falls through to the prefix rules
            assert_eq!(reg.attribute(&k, b"\xffnope"), expect, "key {k:?}");
        }
    });
}

#[test]
fn prop_tenant_bytes_conserved_under_churn() {
    use slabforge::store::sharded::ShardedStore;
    use slabforge::store::store::MetaSetOpts;
    use std::sync::Arc;
    check("tenant byte conservation", 8, |rng| {
        // small pages + small memory: eviction, quota reclaim, and
        // overwrite re-stamping all fire
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                64 << 10,
                4 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        let reg = store.tenants().clone();
        reg.define("t1", b"a:", None).unwrap();
        reg.define("t2", b"b:", Some(1)).unwrap(); // 1-page soft quota
        let mut live: Vec<Vec<u8>> = Vec::new();
        for _ in 0..600 {
            match rng.gen_range(10) {
                0..=5 => {
                    let pre: &[u8] = [&b"a:"[..], b"b:", b"c:"][rng.gen_range(3) as usize];
                    let mut key = pre.to_vec();
                    key.extend_from_slice(&gen::key(rng, 10));
                    let vlen = 1 + rng.gen_range(3000) as usize;
                    let opts = MetaSetOpts {
                        tenant: reg.attribute(&key, b""),
                        ..MetaSetOpts::set(0, 0)
                    };
                    if store.meta_set(&key, &vec![b'v'; vlen], &opts).is_ok() {
                        live.push(key);
                    }
                }
                6 | 7 => {
                    if !live.is_empty() {
                        let i = rng.gen_range(live.len() as u64) as usize;
                        let key = live.swap_remove(i);
                        store.delete(&key);
                        live.retain(|k| k != &key);
                    }
                }
                8 => {
                    store.maintain_all(64);
                }
                _ => {
                    // quota/need arbitration exactly as the maintainer
                    // runs it
                    let mask = reg.arbitration_mask();
                    if mask != 0 {
                        store.reclaim_tenants(mask, 1 + rng.gen_range(64) as usize);
                    }
                }
            }
        }
        // conservation: per-tenant residency gauges sum to exactly what
        // the allocator carries, across eviction/reclaim/overwrite
        let stats = reg.stats_snapshot();
        let tenant_bytes: u64 = stats.iter().map(|t| t.bytes_live).sum();
        let tenant_items: u64 = stats.iter().map(|t| t.items_live).sum();
        let slab = store.slab_stats();
        assert_eq!(tenant_bytes, slab.requested_bytes, "byte conservation");
        assert_eq!(tenant_items, store.len() as u64, "item conservation");
    });
}

// ------------------------------------------------------------ rng sanity

#[test]
fn prop_rng_streams_independent() {
    check("rng independence", 10, |rng| {
        let s1 = rng.next_u64();
        let s2 = s1.wrapping_add(1);
        let a: Vec<u64> = {
            let mut r = Pcg64::new(s1);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Pcg64::new(s2);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, b, "adjacent seeds must diverge");
    });
}

// ------------------------------------------------------------- udp frames

#[test]
fn prop_torn_datagrams_never_poison_the_conn() {
    use slabforge::server::udp::{encode_header, handle_datagram, HEADER_LEN};
    use slabforge::server::{Conn, NoControl};
    use slabforge::store::sharded::ShardedStore;
    use std::sync::Arc;

    check("torn udp datagrams", 20, |rng| {
        let store = Arc::new(
            ShardedStore::with(
                ChunkSizePolicy::default(),
                1 << 20,
                16 << 20,
                true,
                2,
                Clock::System,
            )
            .unwrap(),
        );
        let mut conn = Conn::new(store, Arc::new(NoControl));
        let mut reply = Vec::new();
        for _ in 0..200 {
            // random lengths, often shorter than the 8-byte header;
            // random bytes, so the header fields and any command text
            // are garbage too — must never panic and never wedge
            let len = rng.gen_range(64) as usize;
            let mut d = vec![0u8; len];
            for b in d.iter_mut() {
                *b = rng.gen_range(256) as u8;
            }
            reply.clear();
            let _ = handle_datagram(&mut conn, &d, &mut reply);
        }
        // the same conn, same parser, still answers a clean pipeline
        let mut d = vec![0u8; HEADER_LEN];
        encode_header(&mut d, 7, 0, 1);
        d.extend_from_slice(b"set pk 0 0 2\r\nok\r\nget pk\r\nversion\r\n");
        reply.clear();
        let id = handle_datagram(&mut conn, &d, &mut reply);
        assert_eq!(id, Some(7));
        let text = String::from_utf8_lossy(&reply);
        assert!(
            text.starts_with("STORED\r\nVALUE pk 0 2\r\nok\r\nEND\r\nVERSION"),
            "conn poisoned by garbage datagrams: {text}"
        );
    });
}
