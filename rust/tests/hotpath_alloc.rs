//! Allocation regression guard for the request hot path: after warmup
//! (buffers sized, key present), a `get` hit and a small multiget must
//! perform **zero** heap allocations end-to-end through the connection
//! state machine — receive-buffer parse, shard routing, chunk→buffer
//! copy, response encoding.
//!
//! Lives in its own integration-test binary because the counting
//! `#[global_allocator]` is process-wide.

use slabforge::server::{Conn, NoControl};
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::Clock;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn conn(shards: usize) -> Conn {
    let store = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            32 << 20,
            true,
            shards,
            Clock::System,
        )
        .unwrap(),
    );
    Conn::new(store, Arc::new(NoControl))
}

#[test]
fn get_hit_path_allocates_nothing() {
    let mut c = conn(4);
    let mut out = Vec::with_capacity(64 * 1024);
    c.on_bytes(b"set hot 3 0 11\r\nhello-world\r\n", &mut out);
    assert!(String::from_utf8_lossy(&out).contains("STORED"));

    // warmup: size every reused buffer, fault in the response path
    for _ in 0..4 {
        out.clear();
        c.on_bytes(b"get hot\r\n", &mut out);
        assert!(String::from_utf8_lossy(&out).contains("VALUE hot 3 11"));
    }

    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..1000 {
        out.clear();
        let done = c.on_bytes(b"get hot\r\n", &mut out);
        assert_eq!(done, 1);
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "get hit path performed {delta} heap allocations over 1000 requests"
    );
    assert!(String::from_utf8_lossy(&out).contains("hello-world"));
}

#[test]
fn multiget_steady_state_allocates_nothing() {
    let mut c = conn(4);
    let mut out = Vec::with_capacity(64 * 1024);
    let mut setup = Vec::new();
    for i in 0..16 {
        setup.extend_from_slice(format!("set m{i:02} 0 0 5\r\nv-{i:02}\r\n").as_bytes());
    }
    c.on_bytes(&setup, &mut out);

    let req = b"get m00 m01 m02 m03 m04 m05 m06 m07 m08 m09 m10 m11 m12 m13 m14 m15\r\n";
    for _ in 0..4 {
        out.clear();
        c.on_bytes(req, &mut out);
        assert_eq!(String::from_utf8_lossy(&out).matches("VALUE ").count(), 16);
    }

    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..1000 {
        out.clear();
        let done = c.on_bytes(req, &mut out);
        assert_eq!(done, 1);
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "16-key multiget performed {delta} heap allocations over 1000 requests"
    );
}

#[test]
fn meta_get_hit_path_allocates_nothing() {
    // the meta dialect must ride the same zero-alloc machinery as the
    // classic fast path: in-place flag parse (no token vec), stack
    // base64 decode, read-locked peek, direct response encode
    let mut c = conn(4);
    let mut out = Vec::with_capacity(64 * 1024);
    c.on_bytes(b"ms hot 11 F3\r\nhello-world\r\n", &mut out);
    assert!(String::from_utf8_lossy(&out).contains("HD"));

    // plain mg with the full echo-flag set + base64-keyed mg (aG90 = "hot")
    let req = b"mg hot v f c t s k Oabcd\r\nmg aG90 v b k\r\n";
    for _ in 0..4 {
        out.clear();
        c.on_bytes(req, &mut out);
        let t = String::from_utf8_lossy(&out);
        assert!(t.contains("VA 11 f3"), "{t}");
        assert!(t.contains("kaG90"), "{t}");
    }

    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..1000 {
        out.clear();
        let done = c.on_bytes(req, &mut out);
        assert_eq!(done, 2);
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "meta get hit path performed {delta} heap allocations over 2000 requests"
    );
    assert!(String::from_utf8_lossy(&out).contains("hello-world"));
}

#[test]
fn meta_quiet_miss_path_allocates_nothing() {
    // pipelined quiet misses + mn barrier: the backbone of the
    // meta_pipeline bench scenario must not allocate per miss
    let mut c = conn(4);
    let mut out = Vec::with_capacity(16 * 1024);
    let req = b"mg absent-a v q\r\nmg absent-b v q\r\nmn\r\n";
    for _ in 0..4 {
        out.clear();
        c.on_bytes(req, &mut out);
        assert_eq!(String::from_utf8_lossy(&out), "MN\r\n");
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..1000 {
        out.clear();
        let done = c.on_bytes(req, &mut out);
        assert_eq!(done, 3);
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "quiet miss pipeline performed {delta} heap allocations over 3000 commands"
    );
}

#[test]
fn optimistic_get_and_mg_hits_stay_lock_free_and_alloc_free() {
    // the single-key classic hit and the plain mg hit now ride the
    // optimistic (seqlock) read path; this pins down both properties at
    // once: the probe performs zero heap allocations AND actually
    // resolves optimistically (no seqlock fallbacks — a silent
    // regression to the locked path would still be alloc-free).
    let store = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            32 << 20,
            true,
            4,
            Clock::System,
        )
        .unwrap(),
    );
    let mut c = Conn::new(store.clone(), Arc::new(NoControl));
    let mut out = Vec::with_capacity(64 * 1024);
    c.on_bytes(b"set hot 3 0 11\r\nhello-world\r\n", &mut out);
    assert!(String::from_utf8_lossy(&out).contains("STORED"));

    let req = b"get hot\r\nmg hot v f c t s\r\n";
    for _ in 0..4 {
        out.clear();
        c.on_bytes(req, &mut out);
        let t = String::from_utf8_lossy(&out);
        assert!(t.contains("VALUE hot 3 11"), "{t}");
        assert!(t.contains("VA 11"), "{t}");
    }
    store.reset_stats();

    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..1000 {
        out.clear();
        let done = c.on_bytes(req, &mut out);
        assert_eq!(done, 2);
    }
    let delta = ALLOCS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "optimistic get/mg hit path performed {delta} heap allocations over 2000 requests"
    );
    let st = store.stats();
    assert_eq!(st.get_hits, 2000, "every request was a hit");
    assert_eq!(st.seqlock_fallbacks, 0, "hits resolved lock-free");
}

#[test]
fn set_path_allocation_is_bounded() {
    // sets are allowed to allocate (parsed command, arena/table growth)
    // but must not regress into per-byte or per-token explosions: the
    // steady-state overwrite of an existing key stays under a handful
    // of allocations per request.
    let mut c = conn(1);
    let mut out = Vec::with_capacity(16 * 1024);
    for _ in 0..8 {
        out.clear();
        c.on_bytes(b"set sk 0 0 6\r\nabcdef\r\n", &mut out);
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    let n = 1000u64;
    for _ in 0..n {
        out.clear();
        c.on_bytes(b"set sk 0 0 6\r\nabcdef\r\n", &mut out);
    }
    let per_req = (ALLOCS.load(Ordering::Relaxed) - before) as f64 / n as f64;
    assert!(
        per_req <= 8.0,
        "steady-state set allocates {per_req:.1} times per request"
    );
}
