//! Cross-language integration: the AOT artifacts (L1 Pallas kernel
//! lowered through the L2 jax graphs) executed via PJRT from rust must
//! agree **bit-for-bit** with:
//!
//! 1. the python-side test vectors (`artifacts/testvectors.json`,
//!    written by `python/compile/aot.py` from formula-defined inputs —
//!    regenerated here from the same formulas), and
//! 2. the pure-rust exact evaluator, on real workloads.
//!
//! These tests REQUIRE `make artifacts` to have run; they are skipped
//! (with a loud message) when the artifacts are absent.

use slabforge::config::settings::Algorithm;
use slabforge::optimizer::engine::{optimize, OptimizerParams, RustBackend, WasteBackend};
use slabforge::optimizer::waste::{WasteMap, SENTINEL};
use slabforge::runtime::{XlaService, XlaWasteBackend};
use slabforge::util::histogram::SizeHistogram;
use slabforge::util::json::Json;
use slabforge::util::rng::Pcg64;
use std::path::Path;
use std::sync::Arc;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        None
    }
}

fn service() -> Option<Arc<XlaService>> {
    artifacts_dir().map(|d| XlaService::start(d).expect("artifacts load"))
}

/// The EXACT formula-defined inputs of `aot.py::testvector_inputs` —
/// keep in sync with python/compile/aot.py.
fn testvector_inputs(
    s: usize,
    b: usize,
    k: usize,
) -> (Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>) {
    let hist: Vec<f64> = (0..s as u64)
        .map(|i| ((i.wrapping_mul(2654435761) >> 7) % 97) as f64)
        .collect();
    let sizes: Vec<f64> = (1..=s).map(|i| i as f64).collect();
    let mut configs = vec![SENTINEL as f64; b * k];
    for row in 0..b {
        for col in 0..6 {
            configs[row * k + col] = 100.0 + 13.0 * row as f64 + 150.0 * col as f64;
        }
    }
    let mut config = vec![SENTINEL as f64; k];
    for (i, &c) in [304.0, 384.0, 480.0, 600.0, 752.0, 944.0].iter().enumerate() {
        config[i] = c;
    }
    let mut deltas = vec![0.0; b * k];
    for c in 0..6 {
        deltas[(2 * c) * k + c] = 8.0;
        deltas[(2 * c + 1) * k + c] = -8.0;
    }
    (hist, sizes, configs, config, deltas)
}

#[test]
fn artifact_waste_eval_matches_python_testvectors() {
    let Some(svc) = service() else { return };
    let man = svc.manifest().clone();
    let (hist, sizes, configs, _, _) =
        testvector_inputs(man.s_buckets, man.b_candidates, man.k_classes);
    let got = svc
        .waste_eval(Arc::new(hist), Arc::new(sizes), configs)
        .expect("waste_eval");

    let vectors = Json::parse(
        &std::fs::read_to_string(man.dir.join("testvectors.json")).expect("testvectors.json"),
    )
    .expect("json");
    let want = vectors
        .get("waste_eval")
        .and_then(|v| v.get("waste"))
        .and_then(Json::as_f64_vec)
        .expect("waste vector");
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(g, w, "waste[{i}]: rust-pjrt {g} != python {w}");
    }
}

#[test]
fn artifact_hill_step_matches_python_testvectors() {
    let Some(svc) = service() else { return };
    let man = svc.manifest().clone();
    let (hist, sizes, _, config, deltas) =
        testvector_inputs(man.s_buckets, man.b_candidates, man.k_classes);
    let (best_cfg, best_waste, wastes) = svc
        .hill_step(Arc::new(hist), Arc::new(sizes), config, deltas)
        .expect("hill_step");

    let vectors =
        Json::parse(&std::fs::read_to_string(man.dir.join("testvectors.json")).unwrap()).unwrap();
    let hs = vectors.get("hill_step").expect("hill_step section");
    let want_cfg = hs.get("best_config").and_then(Json::as_f64_vec).unwrap();
    let want_waste = hs.get("best_waste").and_then(Json::as_f64).unwrap();
    let want_wastes = hs.get("wastes").and_then(Json::as_f64_vec).unwrap();
    assert_eq!(best_cfg, want_cfg);
    assert_eq!(best_waste, want_waste);
    assert_eq!(wastes, want_wastes);
}

#[test]
fn artifact_fit_lognormal_matches_python_testvectors() {
    let Some(svc) = service() else { return };
    let man = svc.manifest().clone();
    let (hist, sizes, _, _, _) =
        testvector_inputs(man.s_buckets, man.b_candidates, man.k_classes);
    let (median, sigma, n) = svc
        .fit_lognormal(Arc::new(hist), Arc::new(sizes))
        .expect("fit");
    let vectors =
        Json::parse(&std::fs::read_to_string(man.dir.join("testvectors.json")).unwrap()).unwrap();
    let fit = vectors.get("fit_lognormal").unwrap();
    let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * b.abs().max(1.0);
    assert!(close(median, fit.get("median").unwrap().as_f64().unwrap()));
    assert!(close(sigma, fit.get("sigma_ln").unwrap().as_f64().unwrap()));
    assert_eq!(n, fit.get("n").unwrap().as_f64().unwrap());
}

fn lognormal_hist(median: f64, sigma: f64, n: usize, seed: u64) -> SizeHistogram {
    let mut h = SizeHistogram::new(16384);
    let mut rng = Pcg64::new(seed);
    for _ in 0..n {
        let s = (rng.lognormal(median, sigma).round() as usize).clamp(60, 16384);
        h.record(s);
    }
    h
}

#[test]
fn xla_backend_bit_identical_to_rust_backend() {
    let Some(svc) = service() else { return };
    let hist = lognormal_hist(518.0, 0.126, 50_000, 42);
    let xla = XlaWasteBackend::new(&svc, &hist);
    let rust = RustBackend::new(WasteMap::from_histogram(&hist));

    let mut rng = Pcg64::new(7);
    // random configs of random lengths, including degenerate ones
    let configs: Vec<Vec<u32>> = (0..300)
        .map(|i| {
            let k = 1 + (i % 9);
            (0..k).map(|_| 60 + rng.gen_range(16_000) as u32).collect()
        })
        .collect();
    let got = xla.eval_batch(&configs);
    let want = rust.eval_batch(&configs);
    assert_eq!(got, want, "XLA artifact and rust evaluator diverge");
}

#[test]
fn optimize_with_xla_backend_matches_rust_backend() {
    let Some(svc) = service() else { return };
    let hist = lognormal_hist(1210.0, 0.09, 30_000, 43);
    let current = slabforge::slab::geometry::memcached_default_sizes();
    let params = OptimizerParams {
        algorithm: Algorithm::SteepestDescent,
        ..Default::default()
    };
    let xla_backend = XlaWasteBackend::new(&svc, &hist);
    let rust_backend = RustBackend::new(WasteMap::from_histogram(&hist));
    let a = optimize(&xla_backend, &hist, &current, &params);
    let b = optimize(&rust_backend, &hist, &current, &params);
    // deterministic algorithm + bit-identical evaluators = same trajectory
    assert_eq!(a.new_config, b.new_config);
    assert_eq!(a.new_waste, b.new_waste);
    assert!(a.recovery() > 0.25, "recovery {}", a.recovery());
}

#[test]
fn fused_hill_step_improves_waste() {
    let Some(svc) = service() else { return };
    let hist = lognormal_hist(518.0, 0.126, 20_000, 44);
    let backend = XlaWasteBackend::new(&svc, &hist);
    let man = svc.manifest().clone();

    let config: Vec<u32> = vec![304, 384, 480, 600, 752, 944];
    let current = backend.eval_batch(&[config.clone()])[0];

    // one fused steepest step: ±64 on each class + implicit zero rows
    let k = man.k_classes;
    let mut deltas = vec![0.0f64; man.b_candidates * k];
    for c in 0..config.len() {
        deltas[(2 * c) * k + c] = 64.0;
        deltas[(2 * c + 1) * k + c] = -64.0;
    }
    let (best, best_waste, wastes) = backend.fused_hill_step(&config, &deltas).expect("step");
    assert_eq!(wastes.len(), man.b_candidates);
    assert!(best_waste <= current, "fused step must never regress");
    assert!(best_waste < current, "first step on default config improves");
    assert_eq!(best.len(), config.len());
    // cross-check the chosen config against the rust evaluator
    let rust = RustBackend::new(WasteMap::from_histogram(&hist));
    assert_eq!(rust.eval_batch(&[best.clone()])[0], best_waste);
}

#[test]
fn service_is_shared_across_threads() {
    let Some(svc) = service() else { return };
    let hist = lognormal_hist(518.0, 0.126, 5000, 45);
    let threads: Vec<_> = (0..4)
        .map(|_| {
            let svc = svc.clone();
            let hist = hist.clone();
            std::thread::spawn(move || {
                let backend = XlaWasteBackend::new(&svc, &hist);
                backend.eval_batch(&[vec![304, 600, 944]])[0]
            })
        })
        .collect();
    let results: Vec<u64> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    assert!(results.windows(2).all(|w| w[0] == w[1]));
}
