//! Torn-read stress for the optimistic (seqlock) get path: reader
//! threads hammer `get_optimistic`/`meta_get_optimistic` on a small hot
//! key set while a writer replaces/deletes/re-creates those keys and
//! the main thread runs a live slab migration underneath — the three
//! mutation sources the seqlock protocol must make invisible.
//!
//! Every value is **self-describing**: an 8-byte little-endian version
//! stamp repeated to a version-dependent length. Any splice of two
//! writes — torn bytes, a stale pointer, a mismatched length — breaks
//! the pattern and fails loudly. The `store.seqlock.stall` failpoint
//! widens the copy window (1 ms sleep between the meta copy and the
//! pre-deref revalidation) so writers overtake readers mid-probe far
//! more often than production timing would allow.
//!
//! Seeded: `SLABFORGE_TORN_SEED=<n>` reproduces a run (echoed on
//! stderr). ci.sh runs the fixed default seed, then a random one.

use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::{ReadAttempt, ShardedStore};
use slabforge::store::store::{Clock, MetaGetOpts, ValueRef};
use slabforge::util::failpoint;
use slabforge::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Hot keys all readers and the writer fight over.
const KEYS: usize = 64;
/// Writer operations per run (bounds the test, not wall time).
const WRITER_OPS: usize = 30_000;
const READERS: usize = 4;

fn seed() -> u64 {
    std::env::var("SLABFORGE_TORN_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x70B2_5EED)
}

fn key(i: usize) -> Vec<u8> {
    format!("torn-k{i:02}").into_bytes()
}

/// Version-dependent length: 8..=512 bytes, always a multiple of the
/// 8-byte stamp, always below the optimistic serve cap.
fn len_of(version: u64) -> usize {
    8 * (1 + (version % 64) as usize)
}

/// The value for `version`: the LE stamp repeated to `len_of`.
fn value_of(version: u64) -> Vec<u8> {
    let stamp = version.to_le_bytes();
    let mut v = Vec::with_capacity(len_of(version));
    while v.len() < len_of(version) {
        v.extend_from_slice(&stamp);
    }
    v
}

/// Panics unless `buf` is exactly some version's self-consistent value.
fn check_consistent(buf: &[u8], ctx: &str) {
    assert!(
        buf.len() >= 8 && buf.len() % 8 == 0,
        "{ctx}: torn length {}",
        buf.len()
    );
    let version = u64::from_le_bytes(buf[..8].try_into().unwrap());
    assert_eq!(
        buf.len(),
        len_of(version),
        "{ctx}: length does not match version {version}"
    );
    for (i, chunk) in buf.chunks_exact(8).enumerate() {
        let got = u64::from_le_bytes(chunk.try_into().unwrap());
        assert_eq!(
            got, version,
            "{ctx}: spliced value — block {i} carries version {got}, header says {version}"
        );
    }
}

fn store() -> Arc<ShardedStore> {
    // one shard: every key contends on the same seqlock stripes/table
    Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            32 << 20,
            true,
            1,
            Clock::System,
        )
        .unwrap(),
    )
}

#[test]
fn readers_never_observe_torn_values() {
    let seed = seed();
    eprintln!("torn-read stress: SLABFORGE_TORN_SEED={seed}");
    // fire the stall on ~1 in 40 probes of a matching candidate
    let _fp = failpoint::armed("store.seqlock.stall", "1in40").unwrap();

    let s = store();
    for i in 0..KEYS {
        s.set(&key(i), &value_of(i as u64), 0, 0).unwrap();
    }

    let stop = Arc::new(AtomicBool::new(false));
    let opt_hits = Arc::new(AtomicU64::new(0));

    let readers: Vec<_> = (0..READERS)
        .map(|r| {
            let s = s.clone();
            let stop = stop.clone();
            let opt_hits = opt_hits.clone();
            std::thread::spawn(move || {
                let mut rng = Pcg64::new(seed ^ (0xBEEFu64 << r));
                let mut buf: Vec<u8> = Vec::new();
                let plain = MetaGetOpts::default();
                while !stop.load(Ordering::Relaxed) {
                    let k = key(rng.gen_range(KEYS as u64) as usize);
                    buf.clear();
                    let attempt = if rng.gen_range(4) == 0 {
                        s.meta_get_optimistic(
                            &k,
                            &plain,
                            &mut buf,
                            |c| c.clear(),
                            |c, v: ValueRef<'_>, h| {
                                c.extend_from_slice(v.data);
                                assert!(h.ttl == -1, "items never expire here");
                            },
                        )
                    } else {
                        s.get_optimistic(&k, &mut buf, |c| c.clear(), |c, v: ValueRef<'_>| {
                            c.extend_from_slice(v.data);
                        })
                    };
                    match attempt {
                        ReadAttempt::Hit(()) => {
                            check_consistent(&buf, "optimistic hit");
                            opt_hits.fetch_add(1, Ordering::Relaxed);
                        }
                        ReadAttempt::Miss => {} // deleted — fine
                        ReadAttempt::Fallback => {
                            // the locked path must agree on consistency
                            buf.clear();
                            s.get_with(&k, |v: ValueRef<'_>| {
                                buf.extend_from_slice(v.data)
                            });
                            if !buf.is_empty() {
                                check_consistent(&buf, "locked fallback");
                            }
                        }
                    }
                }
            })
        })
        .collect();

    let writer = {
        let s = s.clone();
        std::thread::spawn(move || {
            let mut rng = Pcg64::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut version: u64 = KEYS as u64;
            for _ in 0..WRITER_OPS {
                let k = key(rng.gen_range(KEYS as u64) as usize);
                match rng.gen_range(10) {
                    0 => {
                        s.delete(&k);
                    }
                    _ => {
                        // replace with a fresh version (length changes
                        // with version, so chunks move between classes)
                        version += 1;
                        s.set(&k, &value_of(version), 0, 0).unwrap();
                    }
                }
            }
        })
    };

    // drive a live migration (twice, both directions) while the race
    // runs: migrate_step rewrites handle/gen/chunk_addr under stripe
    // guards, the exact windows the readers must never see half-done
    s.set_migrate_batch(32);
    for sizes in [vec![128, 320, 704], vec![96, 192, 384, 704]] {
        s.begin_reconfigure(ChunkSizePolicy::Explicit(sizes)).unwrap();
        while s.migration_step_all() {
            std::thread::yield_now();
        }
    }

    writer.join().unwrap();
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        r.join().unwrap();
    }

    // deferred bumps survive the chaos: stale ids are skipped, applied
    // ones leave the store intact
    s.drain_deferred();
    s.check_integrity().expect("post-stress integrity");

    let hits = opt_hits.load(Ordering::Relaxed);
    assert!(
        hits > 0,
        "stress never exercised the optimistic path (0 lock-free hits)"
    );
    let st = s.stats();
    eprintln!(
        "torn-read stress: {hits} optimistic hits, {} retries, {} fallbacks, \
         {} bumps queued / {} drained / {} dropped, stall fired {} times",
        st.seqlock_retries,
        st.seqlock_fallbacks,
        st.lru_bump_queued,
        st.lru_bump_drained,
        st.lru_bump_dropped,
        failpoint::fire_count("store.seqlock.stall"),
    );
}
