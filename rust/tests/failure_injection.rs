//! Failure injection: the system must surface clean errors (never
//! panic, never serve corrupt data) when its environment breaks —
//! stale/corrupt artifacts, malformed configs, abusive clients.

use slabforge::client::Client;
use slabforge::runtime::XlaService;
use slabforge::server::Server;
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::Clock;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("slabforge-fail-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

// ------------------------------------------------------------- artifacts

#[test]
fn missing_artifacts_dir_is_clean_error() {
    let err = XlaService::start(Path::new("/nonexistent/artifacts")).unwrap_err();
    assert!(err.contains("manifest"), "{err}");
}

#[test]
fn truncated_hlo_artifact_fails_at_load_not_at_run() {
    let src = Path::new("artifacts");
    if !src.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing");
        return;
    }
    let dir = tmpdir("trunc");
    std::fs::copy(src.join("manifest.json"), dir.join("manifest.json")).unwrap();
    for f in ["waste_eval.hlo.txt", "hill_step.hlo.txt", "fit_lognormal.hlo.txt"] {
        let text = std::fs::read_to_string(src.join(f)).unwrap();
        std::fs::write(dir.join(f), &text[..text.len() / 3]).unwrap(); // corrupt
    }
    let err = XlaService::start(&dir).unwrap_err();
    assert!(!err.is_empty());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn sentinel_mismatch_detected_before_compile() {
    let src = Path::new("artifacts");
    if !src.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing");
        return;
    }
    let dir = tmpdir("sentinel");
    let manifest = std::fs::read_to_string(src.join("manifest.json")).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        manifest.replace("2097152", "1048576"),
    )
    .unwrap();
    let err = XlaService::start(&dir).unwrap_err();
    assert!(err.contains("sentinel") || err.contains("incompatible"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

// -------------------------------------------------------------- protocol

fn server() -> (slabforge::server::ServerHandle, Arc<ShardedStore>) {
    let store = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            16 << 20,
            true,
            2,
            Clock::System,
        )
        .unwrap(),
    );
    let h = Server::new(store.clone()).start("127.0.0.1:0").unwrap();
    (h, store)
}

#[test]
fn abusive_client_random_bytes_do_not_kill_server() {
    let (h, store) = server();
    let mut rng = slabforge::util::rng::Pcg64::new(666);
    for _ in 0..10 {
        let mut s = TcpStream::connect(h.addr()).unwrap();
        let garbage: Vec<u8> = (0..4096).map(|_| rng.gen_range(256) as u8).collect();
        let _ = s.write_all(&garbage);
        let _ = s.write_all(b"\r\n");
        drop(s);
    }
    // server still serves a well-behaved client
    let mut c = Client::connect(h.addr()).unwrap();
    c.set("alive", b"yes", 0, 0).unwrap();
    assert_eq!(c.get("alive").unwrap().unwrap().value, b"yes");
    assert_eq!(store.get(b"alive").unwrap().value, b"yes");
    h.shutdown();
}

#[test]
fn oversized_line_and_data_rejected_without_desync() {
    let (h, _) = server();
    let mut s = TcpStream::connect(h.addr()).unwrap();
    // huge single line (over MAX_LINE): server errors and closes
    let long = vec![b'a'; 10_000];
    s.write_all(b"get ").unwrap();
    s.write_all(&long).unwrap();
    s.write_all(b"\r\n").unwrap();
    let mut buf = Vec::new();
    let _ = s.read_to_end(&mut buf);
    assert!(
        String::from_utf8_lossy(&buf).contains("CLIENT_ERROR"),
        "{}",
        String::from_utf8_lossy(&buf)
    );

    // oversized data block: error but connection stays in sync
    let mut c = Client::connect(h.addr()).unwrap();
    let err = c.set("big", &vec![0u8; (1 << 20) + 2048], 0, 0).unwrap_err();
    assert!(format!("{err}").contains("SERVER_ERROR"), "{err}");
    c.set("ok", b"fine", 0, 0).unwrap();
    assert_eq!(c.get("ok").unwrap().unwrap().value, b"fine");
    h.shutdown();
}

#[test]
fn half_closed_mid_data_block_is_dropped() {
    let (h, store) = server();
    let mut s = TcpStream::connect(h.addr()).unwrap();
    s.write_all(b"set partial 0 0 100\r\nonly-ten-b").unwrap();
    drop(s); // connection dies mid data block
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert!(store.get(b"partial").is_none(), "partial item must not exist");
    h.shutdown();
}

// --------------------------------------------------------------- config

#[test]
fn invalid_reconfigure_leaves_store_intact() {
    let store = ShardedStore::with(
        ChunkSizePolicy::default(),
        PAGE_SIZE,
        16 << 20,
        true,
        1,
        Clock::System,
    )
    .unwrap();
    store.set(b"k", &vec![b'v'; 500], 0, 0).unwrap();
    // descending sizes -> policy error propagates as StoreError
    let before = store.chunk_sizes();
    assert!(store
        .reconfigure(ChunkSizePolicy::Explicit(vec![900, 400]))
        .is_err());
    assert_eq!(store.chunk_sizes(), before, "config unchanged after failure");
    assert_eq!(store.get(b"k").unwrap().value.len(), 500);
}

#[test]
fn settings_reject_insane_configs() {
    use slabforge::config::Settings;
    for toml in [
        "threads = 0\n",
        "shards = 0\n",
        "[memory]\nlimit = 0\n",
        "[memory]\ngrowth_factor = 0.5\n",
        "[memory]\nslab_sizes = [1]\n",
        "[optimizer]\nbackend = \"gpu\"\n",
    ] {
        assert!(Settings::from_toml(toml).is_err(), "accepted: {toml}");
    }
}
