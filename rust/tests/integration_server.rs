//! Full network-path integration: TCP server + client library +
//! optimizer control plane (`slabs optimize` / `slabs reconfigure` /
//! `stats slabs` over the wire).

use slabforge::client::Client;
use slabforge::config::settings::{Algorithm, Backend, OptimizerSettings};
use slabforge::optimizer::autotune::AutoTuner;
use slabforge::optimizer::collector::SizeCollector;
use slabforge::server::{Server, ServerHandle};
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::Clock;
use slabforge::util::rng::Pcg64;
use slabforge::workload::gen::value_len_for_total;
use std::sync::Arc;

fn full_server(min_samples: u64) -> (ServerHandle, Arc<ShardedStore>) {
    let store = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            64 << 20,
            true,
            2,
            Clock::System,
        )
        .unwrap(),
    );
    let collector = Arc::new(SizeCollector::default());
    store.set_observer(collector.clone());
    let tuner = AutoTuner::new(
        store.clone(),
        collector,
        OptimizerSettings {
            enabled: true,
            min_samples,
            min_improvement: 0.05,
            algorithm: Algorithm::SteepestDescent,
            backend: Backend::Rust,
            ..Default::default()
        },
        PAGE_SIZE,
    )
    .unwrap();
    let handle = Server::with_control(store.clone(), tuner)
        .start("127.0.0.1:0")
        .unwrap();
    (handle, store)
}

fn drive_sets(c: &mut Client, n: usize, seed: u64) {
    let mut rng = Pcg64::new(seed);
    for i in 0..n {
        let total = (rng.lognormal(518.0, 0.126).round() as usize).clamp(70, 16000);
        let vlen = value_len_for_total(total, true).unwrap();
        c.set_noreply(&format!("k{i:08}"), &vec![b'x'; vlen], 0, 0)
            .unwrap();
    }
    // flush pipeline
    let _ = c.version().unwrap();
}

#[test]
fn optimize_over_the_wire_reduces_stats_slabs_waste() {
    let (handle, _store) = full_server(1000);
    let mut c = Client::connect(handle.addr()).unwrap();

    drive_sets(&mut c, 20_000, 7);

    let before = c.stats(None).unwrap();
    let waste_before: u64 = before["bytes_wasted"].parse().unwrap();
    assert!(waste_before > 0);

    let msg = c.slabs_optimize().unwrap();
    assert!(msg.starts_with("APPLIED"), "{msg}");

    let after = c.stats(None).unwrap();
    let waste_after: u64 = after["bytes_wasted"].parse().unwrap();
    assert!(
        (waste_after as f64) < waste_before as f64 * 0.75,
        "waste {waste_before} -> {waste_after}"
    );
    assert_eq!(after["slab_reconfigures"], "2"); // 2 shards

    // data survived the live migration
    assert!(c.get("k00000000").unwrap().is_some());
    assert!(c.get("k00019999").unwrap().is_some());
    handle.shutdown();
}

#[test]
fn manual_reconfigure_over_the_wire() {
    let (handle, store) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();
    c.set("a", &vec![b'x'; 400], 0, 0).unwrap();

    let msg = c.slabs_reconfigure(&[512, 1024, 8192]).unwrap();
    assert!(msg.starts_with("RECONFIGURED items_moved=1"), "{msg}");
    assert_eq!(store.chunk_sizes(), vec![512, 1024, 8192, PAGE_SIZE]);
    assert_eq!(c.get("a").unwrap().unwrap().value.len(), 400);

    // invalid sizes rejected, store untouched
    let err = c.slabs_reconfigure(&[100, 50]).unwrap_err();
    assert!(format!("{err}").contains("SERVER_ERROR"), "{err}");
    assert_eq!(store.chunk_sizes(), vec![512, 1024, 8192, PAGE_SIZE]);
    handle.shutdown();
}

#[test]
fn stats_sizes_reflects_learned_histogram() {
    let (handle, _) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();
    // item total = 48+8+1+343+2 = 402 -> sizes bucket 416 (13*32)
    c.set("k", &vec![b'x'; 343], 0, 0).unwrap();
    let sizes = c.stats(Some("sizes")).unwrap();
    assert_eq!(sizes.get("416").map(String::as_str), Some("1"), "{sizes:?}");
    handle.shutdown();
}

#[test]
fn not_enough_data_reported_over_wire() {
    let (handle, _) = full_server(1_000_000);
    let mut c = Client::connect(handle.addr()).unwrap();
    c.set("k", b"v", 0, 0).unwrap();
    let msg = c.slabs_optimize().unwrap();
    assert!(msg.starts_with("NOT_ENOUGH_DATA"), "{msg}");
    handle.shutdown();
}

#[test]
fn multiget_over_the_wire_preserves_request_order() {
    use std::io::{Read, Write};
    let (handle, _) = full_server(u64::MAX);
    let mut s = std::net::TcpStream::connect(handle.addr()).unwrap();
    let mut payload = Vec::new();
    for i in 0..10 {
        payload.extend_from_slice(format!("set wk{i} 0 0 1 noreply\r\nx\r\n").as_bytes());
    }
    // shuffled request order; keys hash onto both shards
    payload.extend_from_slice(b"get wk9 wk3 wk7 wk0 wk5 wk1 wk8 wk2 wk6 wk4\r\n");
    s.write_all(&payload).unwrap();
    let mut got = Vec::new();
    let mut buf = [0u8; 4096];
    while !String::from_utf8_lossy(&got).contains("END\r\n") {
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "server closed early");
        got.extend_from_slice(&buf[..n]);
    }
    let keys: Vec<String> = String::from_utf8_lossy(&got)
        .lines()
        .filter_map(|l| {
            l.strip_prefix("VALUE ")
                .map(|r| r.split(' ').next().unwrap().to_string())
        })
        .collect();
    assert_eq!(
        keys,
        vec!["wk9", "wk3", "wk7", "wk0", "wk5", "wk1", "wk8", "wk2", "wk6", "wk4"],
        "multiget must answer in request key order"
    );
    handle.shutdown();
}

#[test]
fn concurrent_traffic_during_optimization() {
    let (handle, _) = full_server(500);
    let addr = handle.addr();

    let mut seeder = Client::connect(addr).unwrap();
    drive_sets(&mut seeder, 5_000, 9);

    // writers keep writing while an optimize runs
    let writers: Vec<_> = (0..4)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let mut rng = Pcg64::new(100 + t);
                for i in 0..2000 {
                    let total = (rng.lognormal(518.0, 0.126).round() as usize).clamp(70, 16000);
                    let vlen = value_len_for_total(total, true).unwrap();
                    c.set(&format!("w{t}-{i}"), &vec![b'y'; vlen], 0, 0).unwrap();
                }
            })
        })
        .collect();
    let mut admin = Client::connect(addr).unwrap();
    let msg = admin.slabs_optimize().unwrap();
    assert!(
        msg.starts_with("APPLIED") || msg.starts_with("BELOW_THRESHOLD"),
        "{msg}"
    );
    for w in writers {
        w.join().unwrap();
    }
    // server still consistent
    let mut c = Client::connect(addr).unwrap();
    assert_eq!(c.get("w0-1999").unwrap().unwrap().value[0], b'y');
    handle.shutdown();
}
