//! Full network-path integration: TCP server + client library +
//! optimizer control plane (`slabs optimize` / `slabs reconfigure` /
//! `stats slabs` over the wire).

use slabforge::client::Client;
use slabforge::config::settings::{Algorithm, Backend, OptimizerSettings};
use slabforge::optimizer::autotune::AutoTuner;
use slabforge::optimizer::collector::SizeCollector;
use slabforge::server::{ServeMode, Server, ServerHandle};
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::Clock;
use slabforge::util::rng::Pcg64;
use slabforge::workload::gen::value_len_for_total;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn full_server(min_samples: u64) -> (ServerHandle, Arc<ShardedStore>) {
    let (handle, store, _tuner) = full_server_with_tuner(min_samples);
    (handle, store)
}

fn full_server_with_tuner(min_samples: u64) -> (ServerHandle, Arc<ShardedStore>, Arc<AutoTuner>) {
    let store = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            64 << 20,
            true,
            2,
            Clock::System,
        )
        .unwrap(),
    );
    let collector = Arc::new(SizeCollector::default());
    store.set_observer(collector.clone());
    let tuner = AutoTuner::new(
        store.clone(),
        collector,
        OptimizerSettings {
            enabled: true,
            min_samples,
            min_improvement: 0.05,
            algorithm: Algorithm::SteepestDescent,
            backend: Backend::Rust,
            ..Default::default()
        },
        PAGE_SIZE,
    )
    .unwrap();
    let handle = Server::with_control(store.clone(), tuner.clone())
        .start("127.0.0.1:0")
        .unwrap();
    (handle, store, tuner)
}

fn drive_sets(c: &mut Client, n: usize, seed: u64) {
    let mut rng = Pcg64::new(seed);
    for i in 0..n {
        let total = (rng.lognormal(518.0, 0.126).round() as usize).clamp(70, 16000);
        let vlen = value_len_for_total(total, true).unwrap();
        c.set_noreply(&format!("k{i:08}"), &vec![b'x'; vlen], 0, 0)
            .unwrap();
    }
    // flush pipeline
    let _ = c.version().unwrap();
}

#[test]
fn optimize_over_the_wire_is_async_and_reduces_stats_slabs_waste() {
    let (handle, _store, tuner) = full_server_with_tuner(1000);
    let stop = Arc::new(AtomicBool::new(false));
    let driver = tuner.spawn(stop.clone());
    let mut c = Client::connect(handle.addr()).unwrap();

    drive_sets(&mut c, 20_000, 7);

    let before = c.stats(None).unwrap();
    let waste_before: u64 = before["bytes_wasted"].parse().unwrap();
    assert!(waste_before > 0);

    // async contract: the control reply is immediate, the recovery
    // numbers land in the stats slabs gauges once the drain completes
    let t = Instant::now();
    let msg = c.slabs_optimize().unwrap();
    assert!(msg.starts_with("OPTIMIZING"), "{msg}");
    assert!(
        t.elapsed() < Duration::from_secs(1),
        "optimize must not block on the drain ({:?})",
        t.elapsed()
    );

    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let slabs = c.stats(Some("slabs")).unwrap();
        if slabs["optimize_pending"] == "0"
            && slabs["optimize_runs"] != "0"
            && slabs["migration_active"] == "0"
        {
            assert_eq!(slabs["optimize_applied"], "1", "{slabs:?}");
            let bp: u64 = slabs["optimize_last_recovery_bp"].parse().unwrap();
            assert!(bp > 2500, "recovery gauge {bp} bp");
            break;
        }
        assert!(Instant::now() < deadline, "async optimize never completed");
        std::thread::sleep(Duration::from_millis(20));
    }

    let after = c.stats(None).unwrap();
    let waste_after: u64 = after["bytes_wasted"].parse().unwrap();
    assert!(
        (waste_after as f64) < waste_before as f64 * 0.75,
        "waste {waste_before} -> {waste_after}"
    );
    assert_eq!(after["slab_reconfigures"], "2"); // 2 shards

    // data survived the live migration
    assert!(c.get("k00000000").unwrap().is_some());
    assert!(c.get("k00019999").unwrap().is_some());
    stop.store(true, Ordering::SeqCst);
    driver.join().unwrap();
    handle.shutdown();
}

#[test]
fn manual_reconfigure_over_the_wire() {
    let (handle, store) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();
    c.set("a", &vec![b'x'; 400], 0, 0).unwrap();

    // the command is asynchronous: it kicks off the drain and returns
    let msg = c.slabs_reconfigure(&[512, 1024, 8192]).unwrap();
    assert!(msg.starts_with("MIGRATING"), "{msg}");
    // geometry flips immediately; the item serves from the old
    // generation while the drain is in flight
    assert_eq!(store.chunk_sizes(), vec![512, 1024, 8192, PAGE_SIZE]);
    assert_eq!(c.get("a").unwrap().unwrap().value.len(), 400);

    // no background tuner thread in this test: drive the drain inline
    while store.migration_step_all() {}
    assert_eq!(c.get("a").unwrap().unwrap().value.len(), 400);
    let slabs = c.stats(Some("slabs")).unwrap();
    assert_eq!(slabs["migration_active"], "0", "{slabs:?}");
    assert_eq!(slabs["migration_moved"], "1", "{slabs:?}");

    // invalid sizes rejected, store untouched
    let err = c.slabs_reconfigure(&[100, 50]).unwrap_err();
    assert!(format!("{err}").contains("SERVER_ERROR"), "{err}");
    assert_eq!(store.chunk_sizes(), vec![512, 1024, 8192, PAGE_SIZE]);
    handle.shutdown();
}

/// The control plane must stay off the hot loop: while a large
/// `slabs reconfigure` drains, other connections keep serving with a
/// bounded per-request gap (the shard write lock is only ever held for
/// one `migrate_batch` step at a time).
#[test]
fn reconfigure_under_load_keeps_serving() {
    let (handle, store, tuner) = full_server_with_tuner(u64::MAX);
    let stop = Arc::new(AtomicBool::new(false));
    let driver = tuner.spawn(stop.clone());

    let mut c = Client::connect(handle.addr()).unwrap();
    drive_sets(&mut c, 20_000, 11);
    store.set_migrate_batch(128); // many steps -> many lock release points

    // a second connection serving gets throughout the drain
    let addr = handle.addr();
    let reader = std::thread::spawn(move || {
        let mut c2 = Client::connect(addr).unwrap();
        let mut rng = Pcg64::new(12);
        let mut max_gap = Duration::ZERO;
        let mut ops = 0u64;
        let t0 = Instant::now();
        while t0.elapsed() < Duration::from_secs(5) {
            let key = format!("k{:08}", rng.gen_range(20_000));
            let t = Instant::now();
            let _ = c2.get(&key).unwrap();
            max_gap = max_gap.max(t.elapsed());
            ops += 1;
        }
        (max_gap, ops)
    });

    // kick off the migration; the response must come back immediately
    let t = Instant::now();
    let msg = c.slabs_reconfigure(&[518, 1024, 8192]).unwrap();
    assert!(msg.starts_with("MIGRATING"), "{msg}");
    assert!(
        t.elapsed() < Duration::from_secs(1),
        "kick-off must not block on the drain ({:?})",
        t.elapsed()
    );

    // the background tuner thread drains it while traffic flows
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let slabs = c.stats(Some("slabs")).unwrap();
        if slabs["migration_active"] == "0" {
            break;
        }
        assert!(Instant::now() < deadline, "drain never completed");
        std::thread::sleep(Duration::from_millis(20));
    }

    let (max_gap, ops) = reader.join().unwrap();
    assert!(ops > 100, "reader must have made progress ({ops} ops)");
    // bounded pause: no single get may stall anywhere near the length
    // of the whole drain. The bound is generous for loaded CI machines
    // and overridable (SLABFORGE_TEST_MAX_GAP_MS) for noisier ones —
    // the previous fixed 500 ms tripped on heavily oversubscribed
    // boxes where the *scheduler*, not the store, owns the gap.
    let gap_bound_ms: u64 = std::env::var("SLABFORGE_TEST_MAX_GAP_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);
    assert!(
        max_gap < Duration::from_millis(gap_bound_ms),
        "get stalled {max_gap:?} during migration (bound {gap_bound_ms}ms)"
    );

    // data survived and the new geometry holds
    assert!(c.get("k00000000").unwrap().is_some());
    assert!(c.get("k00019999").unwrap().is_some());
    let slabs = c.stats(Some("slabs")).unwrap();
    let moved: u64 = slabs["migration_moved"].parse().unwrap();
    assert!(moved > 10_000, "most items must have migrated ({moved})");

    stop.store(true, Ordering::SeqCst);
    driver.join().unwrap();
    handle.shutdown();
}

#[test]
fn stats_sizes_reflects_learned_histogram() {
    let (handle, _) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();
    // item total = 48+8+1+343+2 = 402 -> sizes bucket 416 (13*32)
    c.set("k", &vec![b'x'; 343], 0, 0).unwrap();
    let sizes = c.stats(Some("sizes")).unwrap();
    assert_eq!(sizes.get("416").map(String::as_str), Some("1"), "{sizes:?}");
    handle.shutdown();
}

#[test]
fn not_enough_data_reported_over_wire() {
    let (handle, _) = full_server(1_000_000);
    let mut c = Client::connect(handle.addr()).unwrap();
    c.set("k", b"v", 0, 0).unwrap();
    let msg = c.slabs_optimize().unwrap();
    assert!(msg.starts_with("NOT_ENOUGH_DATA"), "{msg}");
    handle.shutdown();
}

#[test]
fn multiget_over_the_wire_preserves_request_order() {
    use std::io::{Read, Write};
    let (handle, _) = full_server(u64::MAX);
    let mut s = std::net::TcpStream::connect(handle.addr()).unwrap();
    let mut payload = Vec::new();
    for i in 0..10 {
        payload.extend_from_slice(format!("set wk{i} 0 0 1 noreply\r\nx\r\n").as_bytes());
    }
    // shuffled request order; keys hash onto both shards
    payload.extend_from_slice(b"get wk9 wk3 wk7 wk0 wk5 wk1 wk8 wk2 wk6 wk4\r\n");
    s.write_all(&payload).unwrap();
    let mut got = Vec::new();
    let mut buf = [0u8; 4096];
    while !String::from_utf8_lossy(&got).contains("END\r\n") {
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "server closed early");
        got.extend_from_slice(&buf[..n]);
    }
    let keys: Vec<String> = String::from_utf8_lossy(&got)
        .lines()
        .filter_map(|l| {
            l.strip_prefix("VALUE ")
                .map(|r| r.split(' ').next().unwrap().to_string())
        })
        .collect();
    assert_eq!(
        keys,
        vec!["wk9", "wk3", "wk7", "wk0", "wk5", "wk1", "wk8", "wk2", "wk6", "wk4"],
        "multiget must answer in request key order"
    );
    handle.shutdown();
}

/// Acceptance gate for the epoll reactor: 256 concurrent sockets, all
/// live at once, each serving a pipelined set+get — handled by at most
/// `reactor_threads` event-loop OS threads (plus accept/tuner), not 256
/// connection threads.
#[test]
fn reactor_serves_256_concurrent_sockets() {
    use std::io::{Read, Write};
    let (handle, _) = full_server(u64::MAX);
    let reactors = handle.reactors();
    assert!(
        (1..=8).contains(&reactors),
        "event mode must be the default, got {reactors} reactors"
    );
    let addr = handle.addr();
    const CONNS: usize = 256;
    let mut socks: Vec<std::net::TcpStream> = (0..CONNS)
        .map(|_| std::net::TcpStream::connect(addr).unwrap())
        .collect();
    // every socket pipelines a noreply set + get of its own key
    for (i, s) in socks.iter_mut().enumerate() {
        s.write_all(
            format!("set ck{i:03} 0 0 4 noreply\r\nv{i:03}\r\nget ck{i:03}\r\n").as_bytes(),
        )
        .unwrap();
    }
    for (i, s) in socks.iter_mut().enumerate() {
        let mut got = Vec::new();
        let mut buf = [0u8; 4096];
        while !String::from_utf8_lossy(&got).contains("END\r\n") {
            let n = s.read(&mut buf).unwrap();
            assert!(n > 0, "server closed socket {i} early");
            got.extend_from_slice(&buf[..n]);
        }
        let t = String::from_utf8_lossy(&got);
        assert!(t.contains(&format!("VALUE ck{i:03} 0 4\r\nv{i:03}")), "{t}");
    }
    // every socket answered while all 256 were still open
    assert!(
        handle.metrics.snapshot().curr_connections >= CONNS as u64,
        "expected >= {CONNS} live connections, saw {}",
        handle.metrics.snapshot().curr_connections
    );
    drop(socks);
    handle.shutdown();
}

/// `stats` must report the reactor's connection gauges (memcached
/// parity: curr/total/rejected connections).
#[test]
fn stats_reports_connection_gauges() {
    let (handle, _) = full_server(u64::MAX);
    let mut c1 = Client::connect(handle.addr()).unwrap();
    let _c2 = Client::connect(handle.addr()).unwrap();
    c1.set("k", b"v", 0, 0).unwrap();
    // wait until the accept thread has registered both clients
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
    while handle.metrics.snapshot().curr_connections < 2 {
        assert!(std::time::Instant::now() < deadline, "conns not registered");
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let stats = c1.stats(None).unwrap();
    let curr: u64 = stats["curr_connections"].parse().unwrap();
    let total: u64 = stats["total_connections"].parse().unwrap();
    assert!(curr >= 2, "curr_connections {curr}");
    assert!(total >= curr, "total {total} < curr {curr}");
    assert!(stats.contains_key("rejected_connections"), "{stats:?}");
    assert!(stats.contains_key("conn_yields"), "{stats:?}");
    handle.shutdown();
}

/// The legacy thread-per-connection mode stays selectable and serves
/// the full protocol path.
#[test]
fn legacy_threaded_mode_over_the_wire() {
    let store = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            64 << 20,
            true,
            2,
            Clock::System,
        )
        .unwrap(),
    );
    let handle = Server::new(store)
        .mode(ServeMode::Threaded)
        .start("127.0.0.1:0")
        .unwrap();
    assert_eq!(handle.reactors(), 0, "threaded mode must not spawn reactors");
    let mut c = Client::connect(handle.addr()).unwrap();
    c.set("lk", b"legacy", 0, 0).unwrap();
    assert_eq!(c.get("lk").unwrap().unwrap().value, b"legacy");
    let stats = c.stats(None).unwrap();
    assert!(stats["curr_connections"].parse::<u64>().unwrap() >= 1);
    handle.shutdown();
}

/// Acceptance: full meta round-trip over TCP — `ms` then `mg` with the
/// `v f c t k O` echo-flag set.
#[test]
fn meta_roundtrip_over_tcp() {
    let (handle, _) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();

    let r = c.ms("mkey", b"hello", &["T60", "F9", "c", "k", "Oreq1"]).unwrap();
    assert_eq!(r.code, "HD", "{r:?}");
    let cas: u64 = r.flag('c').unwrap().parse().unwrap();
    assert_eq!(r.flag('k'), Some("mkey"));
    assert_eq!(r.flag('O'), Some("req1"));

    let r = c.mg("mkey", &["v", "f", "c", "t", "k", "Oreq2"]).unwrap();
    assert_eq!(r.code, "VA");
    assert_eq!(r.data.as_deref(), Some(&b"hello"[..]));
    assert_eq!(r.flag('f'), Some("9"));
    assert_eq!(r.flag('c').unwrap().parse::<u64>().unwrap(), cas);
    let ttl: i64 = r.flag('t').unwrap().parse().unwrap();
    assert!((1..=60).contains(&ttl), "ttl {ttl}");
    assert_eq!(r.flag('k'), Some("mkey"));
    assert_eq!(r.flag('O'), Some("req2"));

    // the same item is visible to the classic dialect
    let v = c.gets("mkey").unwrap().unwrap();
    assert_eq!(v.value, b"hello");
    assert_eq!(v.flags, 9);
    assert_eq!(v.cas, Some(cas));
    handle.shutdown();
}

/// Acceptance: `q` suppresses quiet misses and successes; the `mn`
/// barrier flushes exactly `MN\r\n` behind the surviving responses.
#[test]
fn meta_quiet_pipeline_with_mn_barrier() {
    use std::io::{Read, Write};
    let (handle, _) = full_server(u64::MAX);
    let mut s = std::net::TcpStream::connect(handle.addr()).unwrap();
    s.write_all(
        b"mg miss1 v q\r\nmg miss2 v q\r\nms qk 1 q\r\nx\r\nmg qk v q\r\nmd qk q\r\nmg qk v q\r\nmn\r\n",
    )
    .unwrap();
    let mut got = Vec::new();
    let mut buf = [0u8; 4096];
    while !String::from_utf8_lossy(&got).contains("MN\r\n") {
        let n = s.read(&mut buf).unwrap();
        assert!(n > 0, "server closed early");
        got.extend_from_slice(&buf[..n]);
    }
    // misses suppressed, quiet set/delete suppressed; only the hit and
    // the barrier made it to the wire
    assert_eq!(String::from_utf8_lossy(&got), "VA 1\r\nx\r\nMN\r\n");
    handle.shutdown();
}

/// Acceptance: `T` touch-on-read is observable through the `t` TTL
/// echo on subsequent reads.
#[test]
fn meta_touch_on_read_observable_via_ttl() {
    let (handle, _) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();
    c.ms("tk", b"v", &["T100"]).unwrap();
    let r = c.mg("tk", &["t", "T5000"]).unwrap();
    let ttl: i64 = r.flag('t').unwrap().parse().unwrap();
    assert!((4995..=5000).contains(&ttl), "touch-on-read ttl {ttl}");
    let r = c.mg("tk", &["t"]).unwrap();
    let ttl: i64 = r.flag('t').unwrap().parse().unwrap();
    assert!(ttl > 100, "touch persisted: {ttl}");
    handle.shutdown();
}

/// Acceptance: `N` vivifies a miss into a real (empty) item and marks
/// the winner with `W`.
#[test]
fn meta_vivify_creates_on_miss() {
    let (handle, _) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();
    let r = c.mg("fresh", &["v", "t", "N60"]).unwrap();
    assert_eq!(r.code, "VA");
    assert_eq!(r.data.as_deref(), Some(&b""[..]));
    assert!(r.flags.iter().any(|f| f == "W"), "winner flag: {r:?}");
    let ttl: i64 = r.flag('t').unwrap().parse().unwrap();
    assert!((1..=60).contains(&ttl), "{ttl}");
    // real item: classic sees it; the next mg is a plain hit, not won
    assert_eq!(c.get("fresh").unwrap().unwrap().value, b"");
    let r = c.mg("fresh", &["v", "N60"]).unwrap();
    assert!(!r.flags.iter().any(|f| f == "W"), "{r:?}");
    handle.shutdown();
}

/// Acceptance: `b` base64 keys address the same item as classic
/// commands on the raw key.
#[test]
fn meta_base64_keys_interop_with_classic() {
    use slabforge::util::b64;
    let (handle, _) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();
    // classic write, meta b64 read
    c.set("foo", b"classic-val", 0, 0).unwrap();
    let r = c.mg(&b64::encode(b"foo"), &["v", "k", "b"]).unwrap();
    assert_eq!(r.code, "VA");
    assert_eq!(r.data.as_deref(), Some(&b"classic-val"[..]));
    assert_eq!(r.flag('k'), Some("Zm9v"), "k echo stays encoded: {r:?}");
    // meta b64 write, classic read
    let r = c.ms(&b64::encode(b"bar"), b"meta-val", &["b"]).unwrap();
    assert_eq!(r.code, "HD");
    assert_eq!(c.get("bar").unwrap().unwrap().value, b"meta-val");
    handle.shutdown();
}

/// Large meta values ride the reactor's writev scatter path (>= 4 KiB
/// data blocks are handed to the kernel without a chunk->buffer copy);
/// the wire bytes must be identical either way.
#[test]
fn meta_large_value_over_tcp() {
    let (handle, _) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();
    let big: Vec<u8> = (0..64 * 1024).map(|i| (i % 251) as u8).collect();
    let r = c.ms("big", &big, &["c"]).unwrap();
    assert_eq!(r.code, "HD");
    let r = c.mg("big", &["v", "s"]).unwrap();
    assert_eq!(r.code, "VA");
    assert_eq!(r.flag('s'), Some("65536"));
    assert_eq!(r.data.as_deref(), Some(&big[..]), "scatter path byte-exact");
    handle.shutdown();
}

/// Acceptance: `l` (last-access), `h` (hit-before) and `u` (no-bump)
/// echo flags — the per-item metadata the maintainer owns, surfaced on
/// the wire.
#[test]
fn meta_la_hit_and_nobump_over_tcp() {
    let (handle, _) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();
    c.ms("hk", b"v", &[]).unwrap();
    // a no-bump read reports the pre-state and must not set the bit
    let r = c.mg("hk", &["v", "h", "u"]).unwrap();
    assert_eq!(r.code, "VA");
    assert_eq!(r.flag('h'), Some("0"), "{r:?}");
    let r = c.mg("hk", &["v", "h", "u"]).unwrap();
    assert_eq!(r.flag('h'), Some("0"), "u reads never mark fetched: {r:?}");
    // a normal h read reports the pre-state, then marks the item
    let r = c.mg("hk", &["v", "h", "l"]).unwrap();
    assert_eq!(r.flag('h'), Some("0"), "{r:?}");
    let la: u64 = r.flag('l').unwrap().parse().unwrap();
    assert!(la <= 2, "fresh item, la {la}");
    let r = c.mg("hk", &["v", "h"]).unwrap();
    assert_eq!(r.flag('h'), Some("1"), "{r:?}");
    handle.shutdown();
}

/// Acceptance: the background maintainer does the tier-rebalance work
/// while the server serves — observable through the `stats` counters.
#[test]
fn background_maintainer_rebalances_under_live_server() {
    use slabforge::store::{spawn_maintainer, MaintainerConfig};
    let (handle, store) = full_server(u64::MAX);
    let stop = Arc::new(AtomicBool::new(false));
    let maint = spawn_maintainer(
        store.clone(),
        MaintainerConfig {
            interval_ms: 1,
            batch: 512,
            ..MaintainerConfig::default()
        },
        stop.clone(),
    );
    let mut c = Client::connect(handle.addr()).unwrap();
    for i in 0..3000u32 {
        c.set_noreply(&format!("mk{i:05}"), b"v", 0, 0).unwrap();
    }
    c.version().unwrap(); // drain the pipeline
    let deadline = Instant::now() + Duration::from_secs(10);
    while !store.lru_balanced() {
        assert!(Instant::now() < deadline, "maintainer never converged");
        std::thread::sleep(Duration::from_millis(10));
    }
    let stats = c.stats(None).unwrap();
    let runs: u64 = stats["maintainer_runs"].parse().unwrap();
    let demoted: u64 = stats["maintainer_demoted"].parse().unwrap();
    assert!(runs > 0, "{stats:?}");
    assert!(demoted > 0, "demotion happened off the set path: {stats:?}");
    assert_eq!(c.get("mk00000").unwrap().unwrap().value, b"v");
    stop.store(true, Ordering::SeqCst);
    maint.join().unwrap();
    handle.shutdown();
}

/// CAS-guarded meta delete and arithmetic over the wire.
#[test]
fn meta_cas_delete_and_arith_over_tcp() {
    let (handle, _) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();
    c.ms("n", b"10", &[]).unwrap();
    let r = c.ma("n", &["D5", "v"]).unwrap();
    assert_eq!(r.data.as_deref(), Some(&b"15"[..]));
    let r = c.ma("n", &["MD", "D6", "v", "c"]).unwrap();
    assert_eq!(r.data.as_deref(), Some(&b"9"[..]));
    let cas: u64 = r.flag('c').unwrap().parse().unwrap();
    // guarded delete: wrong CAS -> EX, right CAS -> HD
    let r = c.md("n", &[&format!("C{}", cas + 1)]).unwrap();
    assert_eq!(r.code, "EX");
    assert!(c.get("n").unwrap().is_some());
    let r = c.md("n", &[&format!("C{cas}")]).unwrap();
    assert_eq!(r.code, "HD");
    assert!(c.get("n").unwrap().is_none());
    handle.shutdown();
}

/// Minimal memcached-UDP client: one request datagram per call,
/// response fragments reassembled by sequence number (they may arrive
/// out of order).
#[cfg(target_os = "linux")]
struct UdpClient {
    sock: std::net::UdpSocket,
    next_id: u16,
}

#[cfg(target_os = "linux")]
impl UdpClient {
    fn connect(addr: std::net::SocketAddr) -> UdpClient {
        let sock = std::net::UdpSocket::bind("127.0.0.1:0").unwrap();
        sock.connect(addr).unwrap();
        sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        UdpClient { sock, next_id: 1 }
    }

    fn exchange(&mut self, body: &[u8]) -> Vec<u8> {
        use slabforge::server::udp::{encode_header, parse_header, HEADER_LEN};
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1);
        let mut d = vec![0u8; HEADER_LEN];
        encode_header(&mut d, id, 0, 1);
        d.extend_from_slice(body);
        self.sock.send(&d).unwrap();
        let mut frags: Vec<Option<Vec<u8>>> = Vec::new();
        let mut got = 0usize;
        let mut buf = [0u8; 2048];
        loop {
            let n = self.sock.recv(&mut buf).unwrap();
            let h = parse_header(&buf[..n]).unwrap();
            if h.request_id != id {
                continue; // stray fragment from an earlier exchange
            }
            if frags.is_empty() {
                frags.resize(h.total as usize, None);
            }
            assert_eq!(h.total as usize, frags.len(), "total changed mid-response");
            if frags[h.seq as usize]
                .replace(buf[HEADER_LEN..n].to_vec())
                .is_none()
            {
                got += 1;
            }
            if got == frags.len() {
                return frags.into_iter().flatten().flatten().collect();
            }
        }
    }
}

#[cfg(target_os = "linux")]
fn udp_capable_server() -> (ServerHandle, Arc<ShardedStore>) {
    let store = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            64 << 20,
            true,
            2,
            Clock::System,
        )
        .unwrap(),
    );
    let handle = Server::new(store.clone())
        .udp(true)
        .start("127.0.0.1:0")
        .unwrap();
    (handle, store)
}

#[cfg(target_os = "linux")]
fn seed_value(addr: std::net::SocketAddr, key: &str, val: &[u8]) {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    let mut req = format!("set {key} 0 0 {}\r\n", val.len()).into_bytes();
    req.extend_from_slice(val);
    req.extend_from_slice(b"\r\n");
    s.write_all(&req).unwrap();
    let mut buf = [0u8; 64];
    let n = s.read(&mut buf).unwrap();
    assert!(String::from_utf8_lossy(&buf[..n]).starts_with("STORED"));
}

/// Tentpole acceptance: the UDP front-end runs the *same* Request IR —
/// an identical command script (classic + meta, including a
/// multi-fragment value and an invalidation) must produce byte-identical
/// transcripts over both transports.
#[cfg(target_os = "linux")]
#[test]
fn udp_and_tcp_answer_the_same_script_identically() {
    let big: Vec<u8> = (0..4000).map(|i| b'a' + (i % 26) as u8).collect();
    let steps: Vec<&[u8]> = vec![
        b"set a 0 0 5\r\nhello\r\n",
        b"get a\r\nmg a v f s\r\n",
        b"mg nosuch v k Onope\r\n",
        b"md a I\r\n",
        b"mg a v\r\n", // stale hit: first reader wins the recache (W X)
        b"get big\r\n", // 3 UDP fragments
        b"delete big\r\n",
        b"version\r\n",
    ];

    let tcp_bytes = {
        use std::io::{Read, Write};
        let (handle, _st) = udp_capable_server();
        seed_value(handle.addr(), "big", &big);
        let mut s = std::net::TcpStream::connect(handle.addr()).unwrap();
        let mut script: Vec<u8> = steps.concat();
        script.extend_from_slice(b"quit\r\n");
        s.write_all(&script).unwrap();
        let mut got = Vec::new();
        s.read_to_end(&mut got).unwrap();
        handle.shutdown();
        got
    };

    let udp_bytes = {
        let (handle, _st) = udp_capable_server();
        seed_value(handle.addr(), "big", &big);
        let mut c = UdpClient::connect(handle.addr());
        let mut got = Vec::new();
        for step in &steps {
            got.extend_from_slice(&c.exchange(step));
        }
        let rx = handle.metrics.udp_datagrams_rx.load(Ordering::Relaxed);
        let tx = handle.metrics.udp_datagrams_tx.load(Ordering::Relaxed);
        assert!(rx >= steps.len() as u64, "rx {rx}");
        assert!(tx > steps.len() as u64, "the big get must fragment: tx {tx}");
        handle.shutdown();
        got
    };

    assert!(!tcp_bytes.is_empty());
    assert_eq!(
        tcp_bytes,
        udp_bytes,
        "transports diverged:\nTCP: {}\nUDP: {}",
        String::from_utf8_lossy(&tcp_bytes),
        String::from_utf8_lossy(&udp_bytes)
    );
}

/// A response spanning more than [`MAX_RESPONSE_FRAGS`] datagrams is
/// replaced by a single diagnosable `SERVER_ERROR` frame, and the
/// socket keeps serving.
#[cfg(target_os = "linux")]
#[test]
fn udp_oversized_response_is_replaced_by_server_error() {
    let (handle, _st) = udp_capable_server();
    seed_value(handle.addr(), "huge", &vec![b'h'; 100_000]);
    let mut c = UdpClient::connect(handle.addr());
    let reply = c.exchange(b"get huge\r\n");
    assert_eq!(
        String::from_utf8_lossy(&reply),
        "SERVER_ERROR response too large for udp\r\n"
    );
    assert_eq!(
        handle.metrics.udp_oversized_drops.load(Ordering::Relaxed),
        1
    );
    let reply = c.exchange(b"version\r\n");
    assert!(String::from_utf8_lossy(&reply).starts_with("VERSION"));
    handle.shutdown();
}

/// Tentpole acceptance: with per-reactor `SO_REUSEPORT` listeners the
/// *kernel* distributes accepts — across 64 flows more than one reactor
/// must end up owning sockets, with no accept thread in the path.
#[test]
fn reuseport_distributes_accepts_across_reactors() {
    use std::io::{Read, Write};
    let store = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            64 << 20,
            true,
            2,
            Clock::System,
        )
        .unwrap(),
    );
    let handle = Server::new(store)
        .reactor_threads(4)
        .start("127.0.0.1:0")
        .unwrap();
    if !handle.reuseport() {
        // kernel without SO_REUSEPORT (or threaded fallback): the
        // single-listener path is covered elsewhere
        handle.shutdown();
        return;
    }
    let mut socks = Vec::new();
    for i in 0..64 {
        let mut s = std::net::TcpStream::connect(handle.addr()).unwrap();
        s.write_all(format!("set rp{i:02} 0 0 1\r\nx\r\n").as_bytes())
            .unwrap();
        let mut buf = [0u8; 32];
        let n = s.read(&mut buf).unwrap();
        assert!(
            String::from_utf8_lossy(&buf[..n]).starts_with("STORED"),
            "socket {i}"
        );
        socks.push(s);
    }
    let counts = handle.accept_counts();
    assert_eq!(counts.len(), 4);
    assert_eq!(counts.iter().sum::<u64>(), 64, "{counts:?}");
    let active = counts.iter().filter(|&&c| c > 0).count();
    assert!(active >= 2, "kernel never spread accepts: {counts:?}");
    drop(socks);
    handle.shutdown();
}

/// Meta invalidation (`md I`) and the recache win race (`mg R<ttl>`)
/// over the wire: exactly one reader gets `W`, later readers get `Z`,
/// stale reads carry `X`, and a rewrite re-arms everything.
#[test]
fn meta_invalidate_and_recache_over_tcp() {
    let (handle, _) = full_server(u64::MAX);
    let mut c = Client::connect(handle.addr()).unwrap();
    c.ms("rk", b"v", &["T50"]).unwrap();
    // remaining ttl (~50s) is under the R100 threshold: the first
    // reader wins the recache race, the second is told to wait
    let r = c.mg("rk", &["v", "R100"]).unwrap();
    assert!(r.flags.iter().any(|f| f == "W"), "{r:?}");
    assert!(!r.flags.iter().any(|f| f == "X"), "not stale, just cold: {r:?}");
    let r = c.mg("rk", &["v", "R100"]).unwrap();
    assert!(r.flags.iter().any(|f| f == "Z"), "{r:?}");
    // a threshold below the remaining ttl marks nothing
    let r = c.mg("rk", &["v", "R10"]).unwrap();
    assert!(!r.flags.iter().any(|f| f == "W" || f == "Z"), "{r:?}");
    // rewrite re-arms; `md I` marks stale instead of deleting
    c.ms("rk", b"v2", &[]).unwrap();
    let r = c.md("rk", &["I"]).unwrap();
    assert_eq!(r.code, "HD");
    let r = c.mg("rk", &["v"]).unwrap();
    assert_eq!(r.data.as_deref(), Some(&b"v2"[..]), "stale data still served");
    assert!(r.flags.iter().any(|f| f == "W"), "{r:?}");
    assert!(r.flags.iter().any(|f| f == "X"), "{r:?}");
    let r = c.mg("rk", &["v"]).unwrap();
    assert!(r.flags.iter().any(|f| f == "Z"), "{r:?}");
    assert!(r.flags.iter().any(|f| f == "X"), "{r:?}");
    handle.shutdown();
}

#[test]
fn concurrent_traffic_during_optimization() {
    let (handle, _, tuner) = full_server_with_tuner(500);
    let stop = Arc::new(AtomicBool::new(false));
    let driver = tuner.spawn(stop.clone());
    let addr = handle.addr();

    let mut seeder = Client::connect(addr).unwrap();
    drive_sets(&mut seeder, 5_000, 9);

    // writers keep writing while an optimize runs
    let writers: Vec<_> = (0..4)
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                let mut rng = Pcg64::new(100 + t);
                for i in 0..2000 {
                    let total = (rng.lognormal(518.0, 0.126).round() as usize).clamp(70, 16000);
                    let vlen = value_len_for_total(total, true).unwrap();
                    c.set(&format!("w{t}-{i}"), &vec![b'y'; vlen], 0, 0).unwrap();
                }
            })
        })
        .collect();
    let mut admin = Client::connect(addr).unwrap();
    let msg = admin.slabs_optimize().unwrap();
    assert!(msg.starts_with("OPTIMIZING"), "{msg}");
    for w in writers {
        w.join().unwrap();
    }
    // the background pass completes while/after traffic flows
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let slabs = admin.stats(Some("slabs")).unwrap();
        if slabs["optimize_pending"] == "0"
            && slabs["optimize_runs"] != "0"
            && slabs["migration_active"] == "0"
        {
            break;
        }
        assert!(Instant::now() < deadline, "optimize never completed");
        std::thread::sleep(Duration::from_millis(20));
    }
    // server still consistent
    let mut c = Client::connect(addr).unwrap();
    assert_eq!(c.get("w0-1999").unwrap().unwrap().value[0], b'y');
    stop.store(true, Ordering::SeqCst);
    driver.join().unwrap();
    handle.shutdown();
}
