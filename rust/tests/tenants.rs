//! Multi-tenant attribution over the wire: the `tenants` admin
//! command, per-tenant `stats tenants` counters, and the attribution
//! edge cases — meta `O` token vs key prefix precedence, binary
//! (base64) keys, the default tenant, runtime rule addition, and
//! `stats reset` semantics.

use slabforge::client::Client;
use slabforge::server::{Server, ServerHandle};
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::Clock;
use std::collections::BTreeMap;
use std::sync::Arc;

fn server() -> (ServerHandle, Arc<ShardedStore>) {
    let store = Arc::new(
        ShardedStore::with(
            ChunkSizePolicy::default(),
            PAGE_SIZE,
            64 << 20,
            true,
            2,
            Clock::System,
        )
        .unwrap(),
    );
    let handle = Server::new(store.clone()).start("127.0.0.1:0").unwrap();
    (handle, store)
}

/// `stats tenants` field for one tenant id, parsed as u64.
fn tstat(m: &BTreeMap<String, String>, id: u8, field: &str) -> u64 {
    m[&format!("{id}:{field}")].parse().unwrap()
}

#[test]
fn admin_command_defines_lists_and_rejects() {
    let (handle, _store) = server();
    let mut c = Client::connect(handle.addr()).unwrap();

    // a fresh server knows only the default tenant; bare `tenants`
    // defaults to `list`
    let rows = c.tenants("").unwrap();
    assert_eq!(
        rows,
        vec!["TENANT 0 default prefixes=- tokens=0 quota=0", "END"]
    );

    assert_eq!(c.tenants("define acme a: 4").unwrap(), vec!["OK 1"]);
    assert_eq!(c.tenants("token acme tokA").unwrap(), vec!["OK 1"]);
    assert_eq!(c.tenants("quota acme 8").unwrap(), vec!["OK 1"]);
    let rows = c.tenants("list").unwrap();
    assert_eq!(
        rows,
        vec![
            "TENANT 0 default prefixes=- tokens=0 quota=0",
            "TENANT 1 acme prefixes=a: tokens=1 quota=8",
            "END"
        ]
    );

    // malformed control lines answer CLIENT_ERROR, not silence
    assert!(c.tenants("define onlyname").is_err());
    assert!(c.tenants("define bad2 p: notanumber").is_err());
    assert!(c.tenants("quota ghost 3").is_err(), "unknown tenant");
    assert!(c.tenants("bogus").is_err());
    // the connection survives the errors
    assert_eq!(c.tenants("define beta b:").unwrap(), vec!["OK 2"]);
    handle.shutdown();
}

#[test]
fn meta_token_outranks_prefix_and_unmatched_falls_to_default() {
    let (handle, _store) = server();
    let mut c = Client::connect(handle.addr()).unwrap();
    c.tenants("define pref x:").unwrap(); // id 1
    c.tenants("define tok zz:").unwrap(); // id 2
    c.tenants("token tok T1").unwrap();

    // key matches tenant 1's prefix, but the meta `O` token wins
    assert_eq!(c.ms("x:key", b"v1", &["OT1"]).unwrap().code, "HD");
    // same key without the token: the prefix rule attributes it
    assert_eq!(c.ms("x:key", b"v2", &[]).unwrap().code, "HD");
    // no rule matches: default tenant absorbs it
    c.set("plain", b"v3", 0, 0).unwrap();

    let m = c.stats(Some("tenants")).unwrap();
    assert_eq!(m["0:name"], "default");
    assert_eq!(m["1:name"], "pref");
    assert_eq!(m["2:name"], "tok");
    assert_eq!(tstat(&m, 2, "cmd_set"), 1, "token beats prefix");
    assert_eq!(tstat(&m, 1, "cmd_set"), 1);
    assert_eq!(tstat(&m, 0, "cmd_set"), 1);

    // reads attribute the same way, and hits/misses both count
    assert_eq!(c.mg("x:key", &["v", "OT1"]).unwrap().code, "VA");
    assert!(c.get("x:key").unwrap().is_some());
    assert!(c.get("x:gone").unwrap().is_none());
    let m = c.stats(Some("tenants")).unwrap();
    assert_eq!(
        (tstat(&m, 2, "cmd_get"), tstat(&m, 2, "get_hits")),
        (1, 1)
    );
    assert_eq!(
        (tstat(&m, 1, "cmd_get"), tstat(&m, 1, "get_hits"), tstat(&m, 1, "get_misses")),
        (2, 1, 1)
    );
    handle.shutdown();
}

#[test]
fn binary_keys_attribute_through_b64() {
    let (handle, store) = server();
    let mut c = Client::connect(handle.addr()).unwrap();
    // a prefix with bytes the text protocol forbids can only be
    // defined through the API (the wire grammar is token-based)
    store.tenants().define("bin", b"\xffp:", None).unwrap();

    // b64("\xffp:a") — the store sees the decoded binary key, and so
    // must attribution
    let k = "/3A6YQ==";
    assert_eq!(c.ms(k, b"v", &["b"]).unwrap().code, "HD");
    assert_eq!(c.mg(k, &["v", "b"]).unwrap().code, "VA");

    let m = c.stats(Some("tenants")).unwrap();
    assert_eq!(tstat(&m, 1, "cmd_set"), 1);
    assert_eq!(tstat(&m, 1, "get_hits"), 1);
    assert!(tstat(&m, 1, "bytes") > 0);
    assert_eq!(tstat(&m, 0, "cmd_set"), 0, "nothing leaked to default");
    handle.shutdown();
}

#[test]
fn runtime_rules_apply_to_new_traffic_only() {
    let (handle, _store) = server();
    let mut c = Client::connect(handle.addr()).unwrap();

    // stored before the rule exists: owned by the default tenant
    c.set("a:old", b"before", 0, 0).unwrap();
    c.tenants("define acme a:").unwrap();
    c.set("a:new", b"after", 0, 0).unwrap();

    let m = c.stats(Some("tenants")).unwrap();
    assert_eq!(
        tstat(&m, 1, "curr_items"),
        1,
        "only post-rule residency belongs to the new tenant"
    );
    assert_eq!(
        tstat(&m, 0, "curr_items"),
        1,
        "pre-rule items keep their default-tenant stamp"
    );
    // *requests* follow the current rules, whoever owns the item
    assert!(c.get("a:old").unwrap().is_some());
    let m = c.stats(Some("tenants")).unwrap();
    assert_eq!(tstat(&m, 1, "cmd_get"), 1);

    // overwriting the old key re-stamps it under the new rule
    c.set("a:old", b"rewritten", 0, 0).unwrap();
    let m = c.stats(Some("tenants")).unwrap();
    assert_eq!(tstat(&m, 1, "curr_items"), 2);
    assert_eq!(tstat(&m, 0, "curr_items"), 0);
    handle.shutdown();
}

#[test]
fn stats_reset_clears_counters_but_keeps_rules_and_gauges() {
    let (handle, _store) = server();
    let mut c = Client::connect(handle.addr()).unwrap();
    c.tenants("define acme a: 4").unwrap();
    c.set("a:k", b"payload", 0, 0).unwrap();
    assert!(c.get("a:k").unwrap().is_some());

    let m = c.stats(Some("tenants")).unwrap();
    assert_eq!(tstat(&m, 1, "cmd_set"), 1);
    assert_eq!(tstat(&m, 1, "cmd_get"), 1);
    let live = tstat(&m, 1, "bytes");
    assert!(live > 0);

    c.stats_reset().unwrap();

    let m = c.stats(Some("tenants")).unwrap();
    assert_eq!(tstat(&m, 1, "cmd_set"), 0, "cumulative counters reset");
    assert_eq!(tstat(&m, 1, "cmd_get"), 0);
    assert_eq!(tstat(&m, 1, "bytes_written"), 0);
    assert_eq!(tstat(&m, 1, "bytes"), live, "residency gauges survive");
    assert_eq!(tstat(&m, 1, "curr_items"), 1);
    assert_eq!(tstat(&m, 1, "quota_pages"), 4, "quotas survive");
    // and the rules themselves are untouched
    assert_eq!(
        c.tenants("list").unwrap()[1],
        "TENANT 1 acme prefixes=a: tokens=0 quota=4"
    );
    assert!(c.get("a:k").unwrap().is_some(), "data untouched by reset");
    handle.shutdown();
}
