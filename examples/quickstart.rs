//! Quickstart: embed a slabforge store, watch it waste memory on
//! skewed traffic, learn better slab classes, and apply them live.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use slabforge::config::settings::Algorithm;
use slabforge::optimizer::collector::SizeCollector;
use slabforge::optimizer::engine::{optimize, OptimizerParams, RustBackend};
use slabforge::optimizer::waste::WasteMap;
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::Clock;
use slabforge::util::fmt::{human_bytes, human_pct};
use slabforge::util::rng::Pcg64;
use slabforge::workload::gen::value_len_for_total;
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. a cache with memcached's default slab classes (96 B × 1.25ⁿ)
    let store = Arc::new(ShardedStore::with(
        ChunkSizePolicy::default(),
        PAGE_SIZE,
        64 << 20, // 64 MiB
        true,
        4,
        Clock::System,
    )?);

    // 2. hook up the size collector (the "learning" input)
    let collector = Arc::new(SizeCollector::default());
    store.set_observer(collector.clone());

    // 3. drive log-normal traffic, like the paper's Table 1 (μ = 518 B)
    let mut rng = Pcg64::new(42);
    for i in 0..50_000u32 {
        let total = (rng.lognormal(518.0, 0.126).round() as usize).clamp(70, 16_000);
        let vlen = value_len_for_total(total, true).unwrap();
        store.set(format!("user:{i}").as_bytes(), &vec![b'x'; vlen], 0, 0)?;
    }

    let before = store.slab_stats();
    println!(
        "before: {} requested, {} allocated, {} holes ({})",
        human_bytes(before.requested_bytes as f64),
        human_bytes(before.allocated_bytes as f64),
        human_bytes(before.hole_bytes as f64),
        human_pct(before.hole_fraction()),
    );

    // 4. learn a better configuration from the observed sizes
    let hist = collector.snapshot();
    let backend = RustBackend::new(WasteMap::from_histogram(&hist));
    let report = optimize(
        &backend,
        &hist,
        &store.chunk_sizes(),
        &OptimizerParams {
            algorithm: Algorithm::SteepestDescent,
            ..Default::default()
        },
    );
    println!(
        "learned: {:?}  (predicted recovery {})",
        report.new_span,
        human_pct(report.recovery()),
    );

    // 5. apply it live — items migrate, keys stay readable
    let sizes: Vec<usize> = report.new_config.iter().map(|&c| c as usize).collect();
    store.reconfigure(ChunkSizePolicy::Explicit(sizes))?;

    let after = store.slab_stats();
    println!(
        "after:  {} requested, {} allocated, {} holes ({})",
        human_bytes(after.requested_bytes as f64),
        human_bytes(after.allocated_bytes as f64),
        human_bytes(after.hole_bytes as f64),
        human_pct(after.hole_fraction()),
    );
    println!(
        "recovered {} of wasted memory",
        human_pct(1.0 - after.hole_bytes as f64 / before.hole_bytes as f64),
    );

    // data is intact
    assert!(store.get(b"user:0").is_some());
    assert!(store.get(b"user:49999").is_some());
    println!("all keys still readable — done.");
    Ok(())
}
