//! Regenerate the paper's full evaluation: Tables 1–5 and the data
//! behind Figures 1–10 (written to `results/`).
//!
//! ```bash
//! cargo run --release --example reproduce_paper              # 200k items/table
//! cargo run --release --example reproduce_paper -- --items 1000000  # paper scale
//! cargo run --release --example reproduce_paper -- --algorithm paper # Algorithm 1 verbatim
//! ```

use slabforge::benchkit::paper::{
    experiment_histogram, render_table, run_experiment_with, write_figure_csvs,
};
use slabforge::benchkit::CsvWriter;
use slabforge::config::cli::Args;
use slabforge::config::settings::Algorithm;
use slabforge::optimizer::engine::RustBackend;
use slabforge::optimizer::waste::WasteMap;
use slabforge::workload::PAPER_EXPERIMENTS;
use std::path::Path;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let items: usize = args.flag_or("items", 200_000)?;
    let seed: u64 = args.flag_or("seed", 2020)?;
    let algorithm = match args.flag("algorithm") {
        Some(a) => Algorithm::parse(a).ok_or(format!("unknown algorithm '{a}'"))?,
        None => Algorithm::SteepestDescent,
    };
    let out_dir = Path::new("results");

    println!("# Reproducing Jhabakh Jai & Das (2020), {items} items/table, {algorithm:?}\n");
    let mut summary = CsvWriter::new(
        out_dir.join("tables.csv"),
        "table,items,old_waste,new_waste,recovery_pct,paper_recovery_pct,old_span,new_span",
    );

    for e in &PAPER_EXPERIMENTS {
        let hist = experiment_histogram(e, items, seed + e.table as u64);
        let backend = RustBackend::new(WasteMap::from_histogram(&hist));
        let row = run_experiment_with(e, &hist, &backend, algorithm, seed);
        println!("{}", render_table(&row));

        let (old_fig, new_fig) = write_figure_csvs(e, &hist, &row, out_dir)?;
        println!(
            "  figures: {} {}\n",
            old_fig.display(),
            new_fig.display()
        );
        summary.row(&[
            row.table.to_string(),
            row.items.to_string(),
            row.old_waste.to_string(),
            row.new_waste.to_string(),
            format!("{:.2}", row.recovery * 100.0),
            format!("{:.2}", row.paper_recovery * 100.0),
            format!("{:?}", row.old_span).replace(',', ";"),
            format!("{:?}", row.new_span).replace(',', ";"),
        ]);
    }
    let path = summary.finish()?;
    println!("summary: {}", path.display());
    Ok(())
}
