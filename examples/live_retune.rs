//! END-TO-END DRIVER (DESIGN.md experiment E2E): a live slabforge
//! server on loopback TCP, a real client driving the paper's Table-1
//! log-normal workload through the text protocol, the size collector
//! learning online, the optimizer running through the **AOT XLA
//! artifacts over PJRT** (falling back to the rust backend when
//! `artifacts/` is missing), and a live slab reconfiguration — with
//! throughput and latency measured before and after.
//!
//! ```bash
//! make artifacts && cargo run --release --example live_retune
//! ```

use slabforge::client::Client;
use slabforge::config::settings::{Algorithm, Backend, OptimizerSettings};
use slabforge::optimizer::autotune::AutoTuner;
use slabforge::optimizer::collector::SizeCollector;
use slabforge::server::Server;
use slabforge::slab::policy::ChunkSizePolicy;
use slabforge::slab::PAGE_SIZE;
use slabforge::store::sharded::ShardedStore;
use slabforge::store::store::Clock;
use slabforge::util::fmt::{human_bytes, human_count, human_pct, human_rate};
use slabforge::util::rng::Pcg64;
use slabforge::workload::gen::value_len_for_total;
use std::sync::Arc;
use std::time::{Duration, Instant};

const ITEMS: usize = 200_000;
const GET_PROBES: usize = 20_000;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // ---- launch the full server stack ----------------------------------
    let store = Arc::new(ShardedStore::with(
        ChunkSizePolicy::default(),
        PAGE_SIZE,
        256 << 20,
        true,
        4,
        Clock::System,
    )?);
    let collector = Arc::new(SizeCollector::default());
    store.set_observer(collector.clone());

    let backend = if std::path::Path::new("artifacts/manifest.json").exists() {
        Backend::Xla
    } else {
        eprintln!("note: artifacts/ missing — optimizer will use the rust backend");
        Backend::Rust
    };
    let tuner = AutoTuner::new(
        store.clone(),
        collector.clone(),
        OptimizerSettings {
            enabled: true,
            min_samples: 10_000,
            min_improvement: 0.05,
            algorithm: Algorithm::SteepestDescent,
            backend,
            ..Default::default()
        },
        PAGE_SIZE,
    )
    .map_err(|e| format!("autotuner: {e}"))?;

    let handle = Server::with_control(store.clone(), tuner.clone()).start("127.0.0.1:0")?;
    let addr = handle.addr();
    println!("server on {addr}, optimizer backend: {backend:?}");

    // ---- phase 1: drive the paper's T1 workload over TCP ---------------
    let mut c = Client::connect(addr)?;
    let mut rng = Pcg64::new(2020);
    let t_load = Instant::now();
    for i in 0..ITEMS {
        let total = (rng.lognormal(518.0, 0.126).round() as usize).clamp(70, 16_000);
        let vlen = value_len_for_total(total, true).unwrap();
        c.set_noreply(&format!("k{i:08}"), &vec![b'x'; vlen], 0, 0)?;
    }
    c.version()?; // drain the pipeline
    let load_elapsed = t_load.elapsed();
    println!(
        "loaded {} items in {:.2}s ({})",
        human_count(ITEMS as u64),
        load_elapsed.as_secs_f64(),
        human_rate(ITEMS as f64 / load_elapsed.as_secs_f64()),
    );

    let (thr_before, lat_before) = measure_gets(&mut c, GET_PROBES, 11)?;
    let stats_before = c.stats(None)?;
    let waste_before: u64 = stats_before["bytes_wasted"].parse()?;
    let bytes: u64 = stats_before["bytes"].parse()?;
    println!(
        "before retune: waste {} of {} stored ({}), GET {} p50/p99 {:.0}/{:.0} µs",
        human_bytes(waste_before as f64),
        human_bytes(bytes as f64),
        human_pct(waste_before as f64 / (waste_before + bytes) as f64),
        human_rate(thr_before),
        lat_before.0,
        lat_before.1,
    );

    // ---- phase 2: learned retune via the control plane ------------------
    // `slabs optimize` is asynchronous: the reply comes back instantly
    // and the tuner's background thread runs the pass and pumps the
    // drain; completion is observable in the stats slabs gauges.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let tuner_thread = tuner.spawn(stop.clone());
    let t_opt = Instant::now();
    let msg = c.slabs_optimize()?;
    println!(
        "slabs optimize -> {msg} (reply in {:.0}µs)",
        t_opt.elapsed().as_micros()
    );
    assert!(msg.starts_with("OPTIMIZING"), "expected async kick-off");
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let slabs = c.stats(Some("slabs"))?;
        if slabs["optimize_pending"] == "0"
            && slabs["optimize_runs"] != "0"
            && slabs["migration_active"] == "0"
        {
            break;
        }
        assert!(Instant::now() < deadline, "optimize never completed");
        std::thread::sleep(Duration::from_millis(50));
    }
    println!(
        "optimize + drain completed in {:.2}s (server answered throughout)",
        t_opt.elapsed().as_secs_f64()
    );
    let slabs = c.stats(Some("slabs"))?;
    assert_eq!(slabs["optimize_applied"], "1", "expected retune to apply");
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    tuner_thread.join().unwrap();

    // ---- phase 3: verify live behaviour after migration -----------------
    let (thr_after, lat_after) = measure_gets(&mut c, GET_PROBES, 12)?;
    let stats_after = c.stats(None)?;
    let waste_after: u64 = stats_after["bytes_wasted"].parse()?;
    println!(
        "after retune:  waste {} ({} recovered), GET {} p50/p99 {:.0}/{:.0} µs",
        human_bytes(waste_after as f64),
        human_pct(1.0 - waste_after as f64 / waste_before as f64),
        human_rate(thr_after),
        lat_after.0,
        lat_after.1,
    );
    println!(
        "slab classes now: {:?}",
        store.chunk_sizes().iter().take(24).collect::<Vec<_>>()
    );

    // hard checks (this example doubles as an end-to-end test)
    assert!(waste_after < waste_before / 2, "expected ≥50 % waste recovery");
    assert!(
        thr_after > thr_before * 0.5,
        "throughput must not collapse after migration"
    );
    let v = c.get("k00000000")?.expect("first key survives");
    assert_eq!(v.value[0], b'x');
    assert!(c.get(&format!("k{:08}", ITEMS - 1))?.is_some());
    println!("OK: waste halved, data intact, server responsive.");

    handle.shutdown();
    Ok(())
}

/// Random-key GET storm; returns (ops/s, (p50 µs, p99 µs)).
fn measure_gets(
    c: &mut Client,
    n: usize,
    seed: u64,
) -> Result<(f64, (f64, f64)), Box<dyn std::error::Error>> {
    let mut rng = Pcg64::new(seed);
    let mut lat = Vec::with_capacity(n);
    let t0 = Instant::now();
    for _ in 0..n {
        let key = format!("k{:08}", rng.gen_range(ITEMS as u64));
        let t = Instant::now();
        let _ = c.get(&key)?;
        lat.push(t.elapsed());
    }
    let total = t0.elapsed();
    lat.sort_unstable();
    let pct = |p: f64| lat[((n as f64 * p) as usize).min(n - 1)];
    Ok((
        n as f64 / total.as_secs_f64(),
        (
            pct(0.50).as_secs_f64() * 1e6,
            pct(0.99).as_secs_f64() * 1e6,
        ),
    ))
}

// silence the unused warning when Duration isn't referenced on some paths
#[allow(dead_code)]
const _: Option<Duration> = None;
