//! §6.1 of the paper: best- and worst-case scenarios for the learned
//! slab classes.
//!
//! * Best case — fewer distinct sizes than classes: the algorithm
//!   reaches 100 % storage efficiency (zero holes).
//! * Worst case 1 — item sizes coincide exactly with the default
//!   geometric chunk sizes: the default config is already optimal.
//! * Worst case 2 — frequency ∝ 1.25⁻ⁿ over the default chain: again
//!   nothing to recover.
//!
//! ```bash
//! cargo run --release --example worst_case
//! ```

use slabforge::config::settings::Algorithm;
use slabforge::optimizer::engine::{optimize, OptimizerParams, RustBackend};
use slabforge::optimizer::waste::WasteMap;
use slabforge::slab::geometry::memcached_default_sizes;
use slabforge::util::histogram::SizeHistogram;
use slabforge::util::rng::Pcg64;
use slabforge::workload::spec::SizeDistribution;

fn optimize_case(name: &str, hist: &SizeHistogram) -> (u64, u64) {
    let backend = RustBackend::new(WasteMap::from_histogram(hist));
    let report = optimize(
        &backend,
        hist,
        &memcached_default_sizes(),
        &OptimizerParams {
            algorithm: Algorithm::SteepestDescent,
            ..Default::default()
        },
    );
    println!(
        "{name:<34} old waste {:>12}  new waste {:>12}  recovered {:>7.2}%",
        report.old_waste,
        report.new_waste,
        report.recovery() * 100.0
    );
    (report.old_waste, report.new_waste)
}

fn main() {
    let mut rng = Pcg64::new(61);

    // ---- best case: 3 distinct sizes, 6+ classes ------------------------
    let best = SizeDistribution::Discrete {
        sizes: vec![(333, 1.0), (777, 2.0), (1234, 0.5)],
    };
    let mut h = SizeHistogram::new(16384);
    for _ in 0..100_000 {
        h.record(best.sample(&mut rng, 70, 16384));
    }
    let (_, new) = optimize_case("best case (3 distinct sizes)", &h);
    assert_eq!(new, 0, "paper §6.1: 100% storage efficiency");

    // ---- worst case 1: sizes == default chunk sizes ----------------------
    let chain: Vec<usize> = memcached_default_sizes()
        .into_iter()
        .filter(|&c| (304..=944).contains(&c))
        .collect();
    let exact = SizeDistribution::Discrete {
        sizes: chain.iter().map(|&c| (c, 1.0)).collect(),
    };
    let mut h = SizeHistogram::new(16384);
    for _ in 0..100_000 {
        h.record(exact.sample(&mut rng, 70, 16384));
    }
    let (old, new) = optimize_case("worst case (sizes = default chain)", &h);
    assert_eq!(old, 0, "exact-fit sizes waste nothing under the default");
    assert_eq!(new, 0);

    // ---- worst case 2: geometric 1.25^-n decay over the chain ------------
    let decay = SizeDistribution::GeomDecay {
        chunk_sizes: chain.clone(),
    };
    let mut h = SizeHistogram::new(16384);
    for _ in 0..100_000 {
        h.record(decay.sample(&mut rng, 70, 16384));
    }
    let (old, new) = optimize_case("worst case (1.25^-n decay)", &h);
    assert_eq!(old, new, "default already optimal: nothing recovered");

    // ---- contrast: the paper's T1 shows what a learnable pattern gives ---
    let t1 = SizeDistribution::LogNormal {
        median: 518.0,
        sigma_ln: 0.126,
    };
    let mut h = SizeHistogram::new(16384);
    for _ in 0..100_000 {
        h.record(t1.sample(&mut rng, 70, 16384));
    }
    let (old, new) = optimize_case("contrast: T1 log-normal", &h);
    assert!(new < old / 2);

    println!("\nall §6.1 scenario assertions hold.");
}
