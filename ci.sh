#!/usr/bin/env bash
# CI gate: formatting, lints, release build, full test suite, bench
# smoke (publishes BENCH_server.json with the high-connection scenario).
# Run from anywhere; operates on the rust/ package.
set -euo pipefail
root="$(cd "$(dirname "$0")" && pwd)"
cd "$root/rust"

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test"
cargo test -q

echo "==> chaos suite, fixed seed (deterministic reproduction baseline)"
cargo test -q --test chaos

echo "==> chaos randomized-seed smoke"
chaos_seed="${SLABFORGE_CHAOS_SEED:-$RANDOM$RANDOM}"
echo "    SLABFORGE_CHAOS_SEED=$chaos_seed (rerun with this env to reproduce)"
SLABFORGE_CHAOS_SEED="$chaos_seed" \
    cargo test -q --test chaos randomized_schedule_no_aborts_no_corruption || {
    echo "error: randomized chaos schedule failed with SLABFORGE_CHAOS_SEED=$chaos_seed" >&2
    exit 1
}

echo "==> warm-restart chaos (subprocess SIGTERM/kill-9/corruption matrix)"
cargo test -q --test chaos warm_restart_roundtrip_over_tcp
cargo test -q --test chaos kill_nine_forces_cold_restart
cargo test -q --test chaos manifest_corruption_and_geometry_mismatch_force_cold
cargo test -q --test chaos manifest_write_failure_in_subprocess_degrades_next_boot_to_cold

echo "==> torn-read stress, fixed seed (deterministic reproduction baseline)"
cargo test -q --test torn_read_stress

echo "==> torn-read randomized-seed stress"
torn_seed="${SLABFORGE_TORN_SEED:-$RANDOM$RANDOM}"
echo "    SLABFORGE_TORN_SEED=$torn_seed (rerun with this env to reproduce)"
SLABFORGE_TORN_SEED="$torn_seed" \
    cargo test -q --test torn_read_stress readers_never_observe_torn_values || {
    echo "error: torn-read stress failed with SLABFORGE_TORN_SEED=$torn_seed" >&2
    exit 1
}

echo "==> bench smoke (256-connection sweep + reconfigure-under-load)"
"$root/scripts/bench_server_smoke.sh" --smoke

echo "==> verify reconfig_stall_us landed in BENCH_server.json"
grep -q "reconfig_stall_us" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the reconfigure-under-load row" >&2
    exit 1
}

echo "==> verify meta_pipeline landed in BENCH_server.json"
grep -q "meta_pipeline" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the meta quiet-pipeline row" >&2
    exit 1
}

echo "==> verify set_p99_us landed in BENCH_server.json"
grep -q "set_p99_us" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the set-storm row" >&2
    exit 1
}

echo "==> verify optimize_stall_us landed in BENCH_server.json"
grep -q "optimize_stall_us" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the async-optimize stall dim" >&2
    exit 1
}

echo "==> verify shed_connections landed in BENCH_server.json"
grep -q "shed_connections" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the overload-shedding row" >&2
    exit 1
}

echo "==> verify degraded_get_p99_us landed in BENCH_server.json"
grep -q "degraded_get_p99_us" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the degraded-get dim" >&2
    exit 1
}

echo "==> verify hot_shard_get_mops landed in BENCH_server.json"
grep -q "hot_shard_get_mops" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the hot-shard read-scalability row" >&2
    exit 1
}

echo "==> verify get_p99_contended_us landed in BENCH_server.json"
grep -q "get_p99_contended_us" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the contended-get p99 dim" >&2
    exit 1
}

echo "==> verify accept_rate_conns_s landed in BENCH_server.json"
grep -q "accept_rate_conns_s" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the accept-burst row" >&2
    exit 1
}

echo "==> verify udp_get_kops landed in BENCH_server.json"
grep -q "udp_get_kops" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the udp get-throughput row" >&2
    exit 1
}

echo "==> verify tenant_agg_hit_rate landed in BENCH_server.json"
grep -q "tenant_agg_hit_rate" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the per-tenant learner hit-rate dim" >&2
    exit 1
}

echo "==> verify tenant_hole_bytes landed in BENCH_server.json"
grep -q "tenant_hole_bytes" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the per-tenant learner hole-bytes dim" >&2
    exit 1
}

echo "==> verify restart_warm_ms landed in BENCH_server.json"
grep -q "restart_warm_ms" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the warm-restart recovery row" >&2
    exit 1
}

echo "==> verify restart_items_recovered landed in BENCH_server.json"
grep -q "restart_items_recovered" "$root/BENCH_server.json" || {
    echo "error: BENCH_server.json is missing the warm-restart recovered-items dim" >&2
    exit 1
}

echo "CI OK"
