#!/usr/bin/env bash
# CI gate: formatting, lints, release build, full test suite.
# Run from anywhere; operates on the rust/ package.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test"
cargo test -q

echo "CI OK"
