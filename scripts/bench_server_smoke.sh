#!/usr/bin/env bash
# Bench smoke: run the end-to-end TCP serving benchmark and publish its
# JSON artifact at the repo root so successive PRs have a throughput
# trajectory to diff (BENCH_server.json rows carry ops_per_sec per
# workload: pipelined sets, roundtrip gets, pipelined gets, multigets,
# connection scaling, the 256-connection reactor sweep, and the
# warm-restart recovery row (restart_warm_ms / restart_items_recovered) —
# rows that sweep socket counts also carry a "connections" dimension).
#
# Usage: bench_server_smoke.sh [--smoke]
#   --smoke   shrink the workload (SLABFORGE_BENCH_SMOKE=1) so the full
#             scenario matrix — including 256 sockets — runs in seconds;
#             used by ci.sh.
set -euo pipefail
root="$(cd "$(dirname "$0")/.." && pwd)"
cd "$root/rust"

if [[ "${1:-}" == "--smoke" ]]; then
    export SLABFORGE_BENCH_SMOKE=1
fi

cargo bench --bench bench_server

# the bench binary writes BENCH_server.json into the package root
if [[ -f BENCH_server.json ]]; then
    cp BENCH_server.json "$root/BENCH_server.json"
    echo "published $root/BENCH_server.json"
else
    echo "error: bench did not produce BENCH_server.json" >&2
    exit 1
fi
