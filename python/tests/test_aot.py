"""AOT interchange path validation (python half).

The full executor of the HLO-text artifacts is the rust runtime
(xla_extension 0.5.1 — modern jaxlib dropped HLO-proto compilation from
its python client), so the cross-language *numeric* check lives in
rust/tests/integration_optimizer.rs against ``testvectors.json``.

Here we validate everything checkable from python:
  * the emitted HLO text re-parses (the format rust consumes),
  * its entry computation has the manifest's parameter/result shapes,
  * the StableHLO module it was printed from executes on the PJRT CPU
    client with numerics identical to the direct jax call,
  * the aot CLI writes a coherent manifest + test vectors.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest
from jax._src.lib import xla_client as xc

import jax

from compile import aot, model
from compile.kernels.waste import B_CANDIDATES, K_CLASSES, S_BUCKETS, SENTINEL


def lowered_for(name):
    ep = aot.ENTRY_POINTS[name]
    args = [aot.spec(*shape) for _, shape in ep["args"]]
    return jax.jit(ep["fn"]).lower(*args)


def execute_stablehlo(lowered, args):
    """Compile the StableHLO (the module HLO text is printed from)."""
    client = xc.make_cpu_client()
    exe = client.compile_and_load(
        str(lowered.compiler_ir("stablehlo")), client.local_devices()
    )
    bufs = [client.buffer_from_pyval(np.ascontiguousarray(a)) for a in args]
    return [np.asarray(o) for o in exe.execute(bufs)]


@pytest.mark.parametrize("name", list(aot.ENTRY_POINTS))
def test_hlo_text_reparses_with_entry_shapes(name):
    text = aot.lower_entry(name)
    module = xc._xla.hlo_module_from_text(text)  # what rust's parser does
    rebuilt = module.to_string()
    assert "ENTRY" in rebuilt
    # every input shape appears as an f64 parameter in the text
    for _, shape in aot.ENTRY_POINTS[name]["args"]:
        dims = ",".join(str(d) for d in shape)
        assert f"f64[{dims}]" in text, f"missing f64[{dims}] param in {name}"


@pytest.mark.slow
def test_waste_eval_stablehlo_matches_jax():
    hist, sizes, configs, _, _ = aot.testvector_inputs()
    got = execute_stablehlo(lowered_for("waste_eval"), [hist, sizes, configs])
    (want,) = model.batched_waste(hist, sizes, configs)
    np.testing.assert_array_equal(got[0], np.asarray(want))


@pytest.mark.slow
def test_hill_step_stablehlo_matches_jax():
    hist, sizes, _, config, deltas = aot.testvector_inputs()
    got = execute_stablehlo(lowered_for("hill_step"), [hist, sizes, config, deltas])
    want = model.hill_step(hist, sizes, config, deltas)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, np.asarray(w))


def test_fit_lognormal_stablehlo_matches_jax():
    hist, sizes, _, _, _ = aot.testvector_inputs()
    got = execute_stablehlo(lowered_for("fit_lognormal"), [hist, sizes])
    want = model.fit_lognormal(hist, sizes)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, np.asarray(w), rtol=1e-12)


def test_testvectors_self_consistent():
    """hill_step vectors must satisfy their own argmin relation."""
    hist, sizes, configs, config, deltas = aot.testvector_inputs()
    (waste,) = model.batched_waste(hist, sizes, configs)
    best_cfg, best_w, wastes = model.hill_step(hist, sizes, config, deltas)
    wastes = np.asarray(wastes)
    assert float(best_w) == wastes.min()
    i = int(np.argmin(wastes))
    np.testing.assert_array_equal(np.asarray(best_cfg), config + deltas[i])
    assert np.asarray(waste).shape == (B_CANDIDATES,)
    assert config.shape == (K_CLASSES,)
    assert hist.shape == (S_BUCKETS,)
    assert float(np.asarray(waste).min()) >= 0.0


def test_manifest_and_vectors_written(tmp_path):
    out = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--only", "fit_lognormal"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
    )
    assert r.returncode == 0, r.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["constants"]["s_buckets"] == S_BUCKETS
    assert manifest["constants"]["sentinel"] == SENTINEL
    assert manifest["entry_points"]["fit_lognormal"]["file"] == "fit_lognormal.hlo.txt"
    assert (out / "fit_lognormal.hlo.txt").exists()
