"""L1 kernel vs oracles — the CORE correctness signal.

The Pallas kernel, the jnp reference, the numpy reference and the
arbitrary-precision integer oracle must agree *bit-exactly* (all
quantities are integers < 2^53 carried in f64; see kernels/waste.py).
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import (
    waste_exact,
    waste_exact_batch,
    waste_ref_jnp,
    waste_ref_numpy,
)
from compile.kernels.waste import SENTINEL, waste_eval

RNG = np.random.default_rng


def as_f64(*arrays):
    return tuple(np.asarray(a, dtype=np.float64) for a in arrays)


def run_all(hist, sizes, configs, s_tile, b_tile):
    """Run kernel + both vector references; return (kernel, jnp, numpy)."""
    hist, sizes, configs = as_f64(hist, sizes, configs)
    k = np.asarray(waste_eval(hist, sizes, configs, s_tile=s_tile, b_tile=b_tile))
    j = np.asarray(waste_ref_jnp(hist, sizes, configs))
    n = waste_ref_numpy(hist, sizes, configs)
    return k, j, n


# ---------------------------------------------------------------- fixed cases


def test_single_bucket_single_class():
    # one item of size 100 in a 128-byte chunk: hole = 28
    k, j, n = run_all([1.0], [100.0], [[128.0]], s_tile=1, b_tile=1)
    assert k.tolist() == [28.0]
    assert j.tolist() == [28.0]
    assert n.tolist() == [28.0]


def test_exact_fit_has_zero_waste():
    k, _, _ = run_all([7.0], [128.0], [[128.0]], s_tile=1, b_tile=1)
    assert k.tolist() == [0.0]


def test_uncovered_bucket_charged_sentinel():
    # size 200 > only chunk 128 -> charged SENTINEL - 200
    k, j, n = run_all([3.0], [200.0], [[128.0]], s_tile=1, b_tile=1)
    expected = 3.0 * (SENTINEL - 200.0)
    assert k.tolist() == [expected] == j.tolist() == n.tolist()


def test_smallest_covering_chunk_wins_regardless_of_order():
    # chunks unsorted + duplicated: assignment must still pick 256 for s=200
    cfg = [[1024.0, 256.0, 256.0, 512.0]]
    k, j, n = run_all([1.0], [200.0], cfg, s_tile=1, b_tile=1)
    assert k.tolist() == [56.0] == j.tolist() == n.tolist()


def test_memcached_default_geometry_t1_shape():
    # Paper Table 1 old config over a byte-granular histogram slice:
    # every size in (480, 600] must land in the 600 chunk, etc.
    sizes = np.arange(1.0, 1025.0)
    hist = np.ones_like(sizes)
    cfg = np.array([[304.0, 384.0, 480.0, 600.0, 752.0, 944.0]])
    k, j, n = run_all(hist, sizes, cfg, s_tile=256, b_tile=1)
    exact = waste_exact(hist.astype(int), sizes.astype(int), [304, 384, 480, 600, 752, 944])
    assert k.tolist() == [float(exact)]
    assert j.tolist() == [float(exact)]


def test_zero_histogram_zero_waste():
    sizes = np.arange(1.0, 129.0)
    hist = np.zeros_like(sizes)
    cfg = np.array([[64.0, 128.0]])
    k, _, _ = run_all(hist, sizes, cfg, s_tile=32, b_tile=1)
    assert k.tolist() == [0.0]


def test_batch_rows_independent():
    sizes = np.arange(1.0, 65.0)
    hist = RNG(0).integers(0, 50, 64).astype(np.float64)
    cfgs = np.array([[16.0, 64.0], [32.0, 64.0], [64.0, SENTINEL], [48.0, 64.0]])
    k, j, n = run_all(hist, sizes, cfgs, s_tile=16, b_tile=2)
    # each row equals its own single-row evaluation
    for i in range(4):
        ki, _, _ = run_all(hist, sizes, cfgs[i : i + 1], s_tile=16, b_tile=1)
        assert k[i] == ki[0]
    assert k.tolist() == j.tolist() == n.tolist()


def test_sentinel_padding_is_inert():
    """Padding a config with SENTINEL slots never changes its waste."""
    sizes = np.arange(1.0, 257.0)
    hist = RNG(1).integers(0, 100, 256).astype(np.float64)
    base = np.array([[96.0, 120.0, 152.0, 192.0, 240.0, 304.0]])
    padded = np.concatenate([base, np.full((1, 10), SENTINEL)], axis=1)
    k1, _, _ = run_all(hist, sizes, base, s_tile=64, b_tile=1)
    k2, _, _ = run_all(hist, sizes, padded, s_tile=64, b_tile=1)
    assert k1.tolist() == k2.tolist()


def test_tiling_invariance():
    """Waste must not depend on the tile decomposition."""
    sizes = np.arange(1.0, 513.0)
    hist = RNG(2).integers(0, 1000, 512).astype(np.float64)
    cfgs = RNG(3).integers(1, 600, (8, 5)).astype(np.float64)
    outs = [
        run_all(hist, sizes, cfgs, s_tile=st_, b_tile=bt_)[0]
        for st_, bt_ in [(512, 8), (256, 4), (128, 2), (64, 8), (512, 1)]
    ]
    for o in outs[1:]:
        assert o.tolist() == outs[0].tolist()


def test_aot_default_shapes_smoke():
    """The exact S=16384, B=256, K=64 shapes the artifact is built with."""
    from compile.kernels.waste import B_CANDIDATES, K_CLASSES, S_BUCKETS

    rng = RNG(4)
    sizes = np.arange(1.0, S_BUCKETS + 1.0)
    hist = np.zeros(S_BUCKETS)
    idx = rng.integers(200, 1200, 5000)
    np.add.at(hist, idx, 1.0)
    cfgs = np.full((B_CANDIDATES, K_CLASSES), SENTINEL)
    cfgs[:, :6] = np.sort(rng.integers(100, 2000, (B_CANDIDATES, 6))).astype(float)
    k = np.asarray(waste_eval(*as_f64(hist, sizes, cfgs)))
    n = waste_ref_numpy(hist, sizes, cfgs)
    assert k.tolist() == n.tolist()


# -------------------------------------------------- prefix-sum fast kernel


def test_prefix_kernel_bit_identical_to_dense():
    """§Perf variant: on uniform-width buckets and sorted rows, the
    prefix-sum kernel must match the dense kernel bit-for-bit."""
    from compile.kernels.waste import waste_eval_prefix

    rng = RNG(10)
    for s, width in [(256, 1.0), (512, 4.0)]:
        sizes = np.arange(1.0, s + 1.0) * width
        hist = rng.integers(0, 10_000, s).astype(np.float64)
        cfgs = np.sort(
            rng.integers(1, int(s * width * 1.3), (16, 7)).astype(np.float64), axis=1
        )
        dense = np.asarray(waste_eval(hist, sizes, cfgs))
        fast = np.asarray(waste_eval_prefix(hist, sizes, cfgs))
        assert fast.tolist() == dense.tolist(), f"s={s} width={width}"


def test_prefix_kernel_sentinel_padding_and_tail():
    from compile.kernels.waste import waste_eval_prefix

    sizes = np.arange(1.0, 129.0)
    hist = np.ones(128)
    # config covers only up to 64: tail charged SENTINEL
    cfg = np.full((1, 4), SENTINEL)
    cfg[0, 0] = 64.0
    fast = np.asarray(waste_eval_prefix(hist, sizes, cfg))
    dense = np.asarray(waste_eval(hist, sizes, cfg))
    assert fast.tolist() == dense.tolist()


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_prefix_kernel_matches_exact_oracle_uniform(data):
    """Hypothesis sweep for the fast kernel on its contract domain
    (uniform buckets, ascending rows)."""
    from compile.kernels.waste import waste_eval_prefix

    s = data.draw(st.sampled_from([32, 64, 128]))
    width = data.draw(st.sampled_from([1, 2, 8]))
    b = data.draw(st.sampled_from([1, 2, 4]))
    k = data.draw(st.integers(1, 8))
    size_vals = [(i + 1) * width for i in range(s)]
    hist_vals = data.draw(st.lists(count_strategy, min_size=s, max_size=s))
    cfgs = [
        sorted(
            data.draw(
                st.lists(st.integers(1, s * width * 2), min_size=k, max_size=k)
            )
        )
        for _ in range(b)
    ]
    hist, sizes, configs = as_f64(hist_vals, size_vals, cfgs)
    got = np.asarray(waste_eval_prefix(hist, sizes, configs))
    want = waste_exact_batch(hist_vals, size_vals, cfgs)
    assert got.tolist() == [float(w) for w in want]


def test_batched_waste_handles_unsorted_rows():
    """model.batched_waste sorts rows in-graph, so unsorted inputs keep
    the dense kernel's order-independent semantics."""
    from compile import model

    sizes = np.arange(1.0, 257.0)
    hist = RNG(11).integers(0, 50, 256).astype(np.float64)
    unsorted = np.asarray([[300.0, 64.0, 128.0, SENTINEL]])
    (fast,) = model.batched_waste(hist, sizes, unsorted)
    dense = np.asarray(waste_eval(hist, sizes, unsorted))
    assert np.asarray(fast).tolist() == dense.tolist()


# ------------------------------------------------------------- hypothesis

sizes_strategy = st.integers(min_value=1, max_value=4096)
count_strategy = st.integers(min_value=0, max_value=1_000_000)
chunk_strategy = st.integers(min_value=1, max_value=8192)


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_kernel_matches_exact_oracle(data):
    """Random shapes / histograms / configs: kernel == integer oracle."""
    s = data.draw(st.sampled_from([16, 32, 64, 128]), label="S")
    b = data.draw(st.sampled_from([1, 2, 4, 8]), label="B")
    k = data.draw(st.integers(1, 9), label="K")
    s_tile = data.draw(st.sampled_from([t for t in (8, 16, 32, 64) if s % t == 0]))
    b_tile = data.draw(st.sampled_from([t for t in (1, 2, 4) if b % t == 0]))

    size_vals = sorted(
        data.draw(
            st.lists(sizes_strategy, min_size=s, max_size=s, unique=True), label="sizes"
        )
    )
    hist_vals = data.draw(
        st.lists(count_strategy, min_size=s, max_size=s), label="hist"
    )
    cfgs = [
        data.draw(st.lists(chunk_strategy, min_size=k, max_size=k), label=f"cfg{i}")
        for i in range(b)
    ]

    hist, sizes, configs = as_f64(hist_vals, size_vals, cfgs)
    got = np.asarray(waste_eval(hist, sizes, configs, s_tile=s_tile, b_tile=b_tile))
    want = waste_exact_batch(hist_vals, size_vals, cfgs)
    assert got.tolist() == [float(w) for w in want]


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_monotone_adding_a_class_never_hurts(data):
    """Invariant: adding a chunk size can only reduce (or keep) waste."""
    s = 64
    size_vals = sorted(
        data.draw(st.lists(sizes_strategy, min_size=s, max_size=s, unique=True))
    )
    hist_vals = data.draw(st.lists(count_strategy, min_size=s, max_size=s))
    base_cfg = data.draw(st.lists(chunk_strategy, min_size=3, max_size=3))
    extra = data.draw(chunk_strategy)

    hist, sizes, _ = as_f64(hist_vals, size_vals, [[0.0]])
    w_base = np.asarray(
        waste_eval(hist, sizes, np.asarray([base_cfg], dtype=np.float64), s_tile=32, b_tile=1)
    )[0]
    w_more = np.asarray(
        waste_eval(
            hist,
            sizes,
            np.asarray([base_cfg + [extra]], dtype=np.float64),
            s_tile=32,
            b_tile=1,
        )
    )[0]
    assert w_more <= w_base


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
