"""L2 graph semantics: hill_step and fit_lognormal."""

from __future__ import annotations

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import waste_ref_numpy
from compile.kernels.waste import SENTINEL


def padded_config(chunks, k=8):
    cfg = np.full(k, SENTINEL)
    cfg[: len(chunks)] = chunks
    return cfg


def neighbor_deltas(n_active, k, b, step):
    """Rust-side move matrix: ±step on each active class + a zero row."""
    d = np.zeros((b, k))
    for i in range(n_active):
        d[2 * i, i] = step
        d[2 * i + 1, i] = -step
    return d


def small_workload(seed=0, s=256):
    rng = np.random.default_rng(seed)
    sizes = np.arange(1.0, s + 1.0)
    hist = np.zeros(s)
    idx = rng.integers(40, 200, 2000)
    np.add.at(hist, idx, 1.0)
    return hist, sizes


def test_hill_step_picks_argmin():
    hist, sizes = small_workload()
    cfg = padded_config([64.0, 128.0, 256.0])
    deltas = neighbor_deltas(3, 8, 16, step=8.0)
    best_cfg, best_w, wastes = model.hill_step(hist, sizes, cfg, deltas)
    wastes = np.asarray(wastes)
    i = int(np.argmin(wastes))
    np.testing.assert_array_equal(np.asarray(best_cfg), cfg + deltas[i])
    assert float(best_w) == wastes[i]
    # cross-check all neighbor wastes against the numpy reference
    want = waste_ref_numpy(hist, sizes, cfg[None, :] + deltas)
    np.testing.assert_array_equal(wastes, want)


def test_hill_step_zero_row_never_regresses():
    """With a zero-delta row present, the step's waste <= current waste."""
    hist, sizes = small_workload(seed=3)
    cfg = padded_config([50.0, 100.0, 199.0])
    deltas = neighbor_deltas(3, 8, 16, step=4.0)  # row 6.. are zero rows
    _, best_w, _ = model.hill_step(hist, sizes, cfg, deltas)
    current = waste_ref_numpy(hist, sizes, cfg[None, :])[0]
    assert float(best_w) <= current


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), step=st.sampled_from([1.0, 2.0, 8.0, 32.0]))
def test_hill_step_invariants(seed, step):
    hist, sizes = small_workload(seed=seed)
    rng = np.random.default_rng(seed + 1)
    chunks = np.sort(rng.integers(8, 300, size=4)).astype(float)
    cfg = padded_config(list(chunks))
    deltas = neighbor_deltas(4, 8, 16, step=step)
    best_cfg, best_w, wastes = model.hill_step(hist, sizes, cfg, deltas)
    wastes = np.asarray(wastes)
    assert float(best_w) == wastes.min()
    assert float(best_w) <= waste_ref_numpy(hist, sizes, cfg[None, :])[0]
    # best_cfg is one of the candidates (hill_step returns sorted rows)
    cands = np.sort(cfg[None, :] + deltas, axis=1)
    assert any(np.array_equal(np.asarray(best_cfg), row) for row in cands)


def test_fit_lognormal_recovers_parameters():
    rng = np.random.default_rng(7)
    mu, sigma_ln = 518.0, 0.126
    samples = np.clip(
        rng.lognormal(np.log(mu), sigma_ln, size=200_000).astype(int), 1, 4095
    )
    hist = np.bincount(samples, minlength=4096).astype(float)
    sizes = np.arange(1.0, 4097.0)
    med, sig, n = model.fit_lognormal(hist, sizes)
    assert float(n) == 200_000
    assert abs(float(med) - mu) / mu < 0.02
    assert abs(float(sig) - sigma_ln) / sigma_ln < 0.05


def test_fit_lognormal_empty_histogram():
    hist = np.zeros(64)
    sizes = np.arange(1.0, 65.0)
    med, sig, n = model.fit_lognormal(hist, sizes)
    assert (float(med), float(sig), float(n)) == (0.0, 0.0, 0.0)
