"""L2 — JAX compute graphs for the slab-class optimizer.

Three entry points, all AOT-lowered to HLO text by ``aot.py`` and
executed from the rust coordinator via PJRT (python never runs on the
request path):

* ``batched_waste(hist, sizes, configs) -> (waste,)``
  Score B candidate configurations in one call (L1 Pallas kernel).

* ``hill_step(hist, sizes, config, deltas) -> (best_config, best_waste, wastes)``
  One steepest-descent step of the paper's hill climber, fused in-graph:
  expand the current configuration into its neighbor set
  ``config + deltas`` (the rust side supplies the move matrix — rows of
  ±step·e_k, plus a zero row so "stay" is always a candidate), score all
  neighbors through the kernel, and return the argmin. One PJRT call per
  optimization step; no per-neighbor host round-trips.

* ``fit_lognormal(hist, sizes) -> (mu, sigma_ln, n)``
  Method-of-moments fit of the traffic pattern in log space — the
  "learning" half of the paper's title. Returns the median (= e^m) and
  the log-space standard deviation; the coordinator uses these to decide
  when the learned pattern has drifted enough to re-run the optimizer.

All f64 (see waste.py — integer quantities < 2^53 are exact, so rust,
kernel and oracle agree bit-for-bit).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.waste import waste_eval, waste_eval_prefix

jax.config.update("jax_enable_x64", True)


def batched_waste(hist, sizes, configs):
    """f64[S], f64[S], f64[B,K] -> (f64[B],).

    Uses the prefix-sum kernel (§Perf: ~400× faster than the dense
    assignment kernel at identical — bit-exact — results). Requires
    ascending candidate rows and uniform-width buckets; both are
    guaranteed by the rust coordinator (`XlaWasteBackend` sorts rows,
    `SizeHistogram::bucketize` emits uniform buckets). The dense kernel
    (`waste_eval`) remains the order-independent reference, validated
    against this one in python/tests/test_kernel.py.
    """
    # jnp.sort makes unsorted rows legal at negligible cost (B·K log K),
    # preserving the dense kernel's order-independent semantics.
    return (waste_eval_prefix(hist, sizes, jnp.sort(configs, axis=1)),)


def batched_waste_dense(hist, sizes, configs):
    """Reference entry point on the dense O(B·K·S) kernel."""
    return (waste_eval(hist, sizes, configs),)


def hill_step(hist, sizes, config, deltas):
    """One fused steepest-descent step.

    Args:
      hist:   f64[S]    bucket counts
      sizes:  f64[S]    bucket representative sizes
      config: f64[K]    current chunk sizes (SENTINEL-padded)
      deltas: f64[B,K]  move matrix; row b turns the current config into
                        neighbor ``config + deltas[b]``. The rust side
                        zeroes rows beyond the active neighbor count and
                        always includes a zero row, so the step never
                        regresses.

    Returns:
      (best_config f64[K], best_waste f64[], wastes f64[B])
    """
    candidates = jnp.sort(config[None, :] + deltas, axis=1)  # [B, K]
    wastes = waste_eval_prefix(hist, sizes, candidates)  # [B]
    best = jnp.argmin(wastes)
    return candidates[best], wastes[best], wastes


def fit_lognormal(hist, sizes):
    """Method-of-moments log-normal fit over the histogram.

    Returns (median = e^m, sigma_ln, n_items). Zero-count histograms
    return (0, 0, 0) rather than NaN so the rust side can branch on n.
    """
    n = jnp.sum(hist)
    safe_n = jnp.maximum(n, 1.0)
    log_s = jnp.log(jnp.maximum(sizes, 1.0))
    mean_ln = jnp.sum(hist * log_s) / safe_n
    var_ln = jnp.sum(hist * (log_s - mean_ln) ** 2) / safe_n
    sigma_ln = jnp.sqrt(jnp.maximum(var_ln, 0.0))
    median = jnp.exp(mean_ln)
    has_data = n > 0
    return (
        jnp.where(has_data, median, 0.0),
        jnp.where(has_data, sigma_ln, 0.0),
        n,
    )
