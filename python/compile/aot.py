"""AOT compiler: lower the L2 graphs to HLO *text* artifacts for rust.

Interchange format is HLO text, NOT a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs (in ``artifacts/``):

  waste_eval.hlo.txt     batched_waste(hist[S], sizes[S], configs[B,K])
  hill_step.hlo.txt      hill_step(hist[S], sizes[S], config[K], deltas[B,K])
  fit_lognormal.hlo.txt  fit_lognormal(hist[S], sizes[S])
  manifest.json          shapes + constants the rust runtime validates
                         against at load time

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
``make artifacts`` wraps this and is a no-op when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels.waste import B_CANDIDATES, K_CLASSES, S_BUCKETS, SENTINEL  # noqa: E402

F64 = jnp.float64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F64)


ENTRY_POINTS = {
    "waste_eval": {
        "fn": model.batched_waste,
        "args": [
            ("hist", (S_BUCKETS,)),
            ("sizes", (S_BUCKETS,)),
            ("configs", (B_CANDIDATES, K_CLASSES)),
        ],
        "outputs": [("waste", (B_CANDIDATES,))],
    },
    "hill_step": {
        "fn": model.hill_step,
        "args": [
            ("hist", (S_BUCKETS,)),
            ("sizes", (S_BUCKETS,)),
            ("config", (K_CLASSES,)),
            ("deltas", (B_CANDIDATES, K_CLASSES)),
        ],
        "outputs": [
            ("best_config", (K_CLASSES,)),
            ("best_waste", ()),
            ("wastes", (B_CANDIDATES,)),
        ],
    },
    "fit_lognormal": {
        "fn": model.fit_lognormal,
        "args": [("hist", (S_BUCKETS,)), ("sizes", (S_BUCKETS,))],
        "outputs": [("median", ()), ("sigma_ln", ()), ("n", ())],
    },
}


def lower_entry(name: str) -> str:
    ep = ENTRY_POINTS[name]
    args = [spec(*shape) for _, shape in ep["args"]]
    lowered = jax.jit(ep["fn"]).lower(*args)
    return to_hlo_text(lowered)


def testvector_inputs():
    """Deterministic, formula-defined inputs for cross-language checks.

    The rust integration tests regenerate these EXACT arrays from the
    same formulas (no RNG, no serialization of 16k-element inputs) and
    assert the artifact outputs match ``testvectors.json`` bit-for-bit.
    Keep in sync with rust/tests/integration_optimizer.rs.
    """
    import numpy as np

    s, b, k = S_BUCKETS, B_CANDIDATES, K_CLASSES
    i = np.arange(s, dtype=np.uint64)
    hist = ((i * np.uint64(2654435761)) >> np.uint64(7)) % np.uint64(97)
    hist = hist.astype(np.float64)
    sizes = np.arange(1.0, s + 1.0)
    configs = np.full((b, k), SENTINEL)
    for col in range(6):
        configs[:, col] = 100.0 + 13.0 * np.arange(b) + 150.0 * col
    config = np.full(k, SENTINEL)
    config[:6] = [304.0, 384.0, 480.0, 600.0, 752.0, 944.0]
    deltas = np.zeros((b, k))
    for c in range(6):
        deltas[2 * c, c] = 8.0
        deltas[2 * c + 1, c] = -8.0
    return hist, sizes, configs, config, deltas


def emit_test_vectors(out_dir: str) -> None:
    import numpy as np

    hist, sizes, configs, config, deltas = testvector_inputs()
    (waste,) = model.batched_waste(hist, sizes, configs)
    best_cfg, best_w, wastes = model.hill_step(hist, sizes, config, deltas)
    med, sig, n = model.fit_lognormal(hist, sizes)
    vectors = {
        "waste_eval": {"waste": np.asarray(waste).tolist()},
        "hill_step": {
            "best_config": np.asarray(best_cfg).tolist(),
            "best_waste": float(best_w),
            "wastes": np.asarray(wastes).tolist(),
        },
        "fit_lognormal": {
            "median": float(med),
            "sigma_ln": float(sig),
            "n": float(n),
        },
    }
    path = os.path.join(out_dir, "testvectors.json")
    with open(path, "w") as f:
        json.dump(vectors, f)
        f.write("\n")
    print(f"wrote {path}", file=sys.stderr)


def input_fingerprint() -> str:
    """Hash of the compile-path sources, so `make artifacts` can no-op."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                p = os.path.join(root, f)
                h.update(p.encode())
                with open(p, "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", nargs="*", default=None, help="subset of entry points to build"
    )
    ns = ap.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)

    names = ns.only or list(ENTRY_POINTS)
    manifest = {
        "format": "hlo-text",
        "dtype": "f64",
        "fingerprint": input_fingerprint(),
        "constants": {
            "s_buckets": S_BUCKETS,
            "b_candidates": B_CANDIDATES,
            "k_classes": K_CLASSES,
            "sentinel": SENTINEL,
        },
        "entry_points": {},
    }
    for name in names:
        text = lower_entry(name)
        fname = f"{name}.hlo.txt"
        path = os.path.join(ns.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        ep = ENTRY_POINTS[name]
        manifest["entry_points"][name] = {
            "file": fname,
            "inputs": [{"name": n, "shape": list(s)} for n, s in ep["args"]],
            "outputs": [{"name": n, "shape": list(s)} for n, s in ep["outputs"]],
        }
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {ns.out_dir}/manifest.json", file=sys.stderr)

    if not ns.only:
        emit_test_vectors(ns.out_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
