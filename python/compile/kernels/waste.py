"""L1 — Pallas kernel: batched memory-hole (internal fragmentation) evaluation.

This is the numeric hot spot of the paper's hill-climbing optimizer
(Algorithm 1): *"Find the Current Memory waste"* is evaluated once per
candidate slab-class configuration, thousands of times per optimization
run. We batch it: one kernel invocation scores ``B`` candidate
configurations against the observed item-size histogram.

Semantics (matching memcached's slab allocator exactly):

  For a histogram bucket with representative size ``s`` and count ``h``,
  an item of size ``s`` is stored in the smallest chunk ``c`` in the
  configuration with ``c >= s``; the memory hole is ``h * (c - s)``.
  A bucket not covered by any chunk (``s`` larger than every class)
  cannot be stored at all; it is charged the ``SENTINEL`` chunk
  (2 MiB > the 1 MiB page-size cap) so that non-covering configurations
  can never win an argmin against covering ones.

Inputs (shapes fixed at AOT time, values free at run time):

  hist:    f64[S]     bucket counts
  sizes:   f64[S]     bucket representative sizes (bytes); byte-granular
                      when ``sizes[i] = i + 1``, coarser buckets are
                      expressed by passing each bucket's *upper* edge
                      (conservative waste estimate)
  configs: f64[B, K]  candidate chunk sizes; rows need NOT be sorted or
                      deduplicated (the masked-min assignment is
                      order-independent); unused class slots are padded
                      with ``SENTINEL``

Output:

  waste:   f64[B]     total wasted bytes per candidate

Everything is f64: all quantities are integers < 2^53, so the kernel is
*bit-exact* against the integer oracle in ``ref.py`` and the rust
evaluator — no tolerance needed in tests.

Hardware adaptation (the paper is CPU-only; we shape the kernel for TPU
anyway, per DESIGN.md §5): the histogram is streamed through VMEM in
``(S_TILE,)`` blocks via BlockSpec, candidates live in a ``(B_TILE, K)``
VMEM-resident block, and chunk assignment is a dense masked min over the
K axis (VPU-friendly; no gather/searchsorted). The per-candidate partial
sums accumulate in the output ref across the sequential S grid
dimension. On this image the kernel runs under ``interpret=True``
(CPU PJRT cannot execute Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 2 MiB: strictly larger than memcached's 1 MiB page-size cap, so an
# uncovered bucket always costs more than any legal assignment.
SENTINEL = float(2 << 20)

# Default AOT shapes (see python/compile/aot.py and artifacts/manifest.json).
S_BUCKETS = 16384  # byte-granular up to 16 KiB; larger via coarse buckets
B_CANDIDATES = 256  # candidates scored per call (>= 2*K + 1 for hill steps)
K_CLASSES = 64  # >= memcached's maximum of 63 slab classes

# Tile shapes: chosen so the VMEM-resident working set
#   hist + sizes tiles: 2 * S_TILE * 8 B        =  32 KiB
#   config block:       B_TILE * K * 8 B        = 128 KiB  (K = 64)
#   chunk/cand scratch: 2 * B_TILE * S_TILE * 8 = 8 MiB f64 (4 MiB in the
#                       f32 TPU variant) — within a 16 MiB/core VMEM budget.
S_TILE = 2048
B_TILE = 64


def _waste_kernel(hist_ref, sizes_ref, cfg_ref, out_ref, *, k_classes: int):
    """One (B_TILE, S_TILE) grid cell: partial waste for a candidate tile."""
    sizes = sizes_ref[...]  # [S_TILE]
    hist = hist_ref[...]  # [S_TILE]
    cfg = cfg_ref[...]  # [B_TILE, K]

    # Smallest covering chunk per (candidate, bucket): masked min over K.
    # The K loop is unrolled at trace time (K is static); each step is a
    # dense [B_TILE, S_TILE] select+min — no gather, MXU/VPU friendly.
    chunk = jnp.full((cfg.shape[0], sizes.shape[0]), SENTINEL, dtype=cfg.dtype)
    for k in range(k_classes):
        c_k = cfg[:, k : k + 1]  # [B_TILE, 1]
        covers = c_k >= sizes[None, :]
        chunk = jnp.minimum(chunk, jnp.where(covers, c_k, SENTINEL))

    partial = jnp.sum((chunk - sizes[None, :]) * hist[None, :], axis=1)

    # Accumulate across the sequential S grid dimension (rightmost-fastest),
    # revisiting the same output block for each S tile.
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(s_idx != 0)
    def _acc():
        out_ref[...] += partial


def _largest_divisor_tile(extent: int, cap: int) -> int:
    """Largest divisor of ``extent`` that is <= ``cap``."""
    tile = min(extent, cap)
    while extent % tile:
        tile -= 1
    return tile


@functools.partial(jax.jit, static_argnames=("s_tile", "b_tile"))
def waste_eval(
    hist: jax.Array,
    sizes: jax.Array,
    configs: jax.Array,
    *,
    s_tile: int | None = None,
    b_tile: int | None = None,
) -> jax.Array:
    """Batched waste: f64[S], f64[S], f64[B, K] -> f64[B].

    Tile shapes default to the largest divisors of S/B within the VMEM
    budget (S_TILE/B_TILE); explicit tiles must divide S/B exactly.
    """
    s_buckets = hist.shape[0]
    b_cands, k_classes = configs.shape
    if s_tile is None:
        s_tile = _largest_divisor_tile(s_buckets, S_TILE)
    if b_tile is None:
        b_tile = _largest_divisor_tile(b_cands, B_TILE)
    if sizes.shape != (s_buckets,):
        raise ValueError(f"sizes shape {sizes.shape} != hist shape {hist.shape}")
    if s_buckets % s_tile or b_cands % b_tile:
        raise ValueError(
            f"S={s_buckets} %% s_tile={s_tile} or B={b_cands} %% b_tile={b_tile} != 0"
        )

    grid = (b_cands // b_tile, s_buckets // s_tile)
    return pl.pallas_call(
        functools.partial(_waste_kernel, k_classes=k_classes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s_tile,), lambda b, s: (s,)),  # hist
            pl.BlockSpec((s_tile,), lambda b, s: (s,)),  # sizes
            pl.BlockSpec((b_tile, k_classes), lambda b, s: (b, 0)),  # configs
        ],
        out_specs=pl.BlockSpec((b_tile,), lambda b, s: (b,)),
        out_shape=jax.ShapeDtypeStruct((b_cands,), configs.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(hist, sizes, configs)


# ---------------------------------------------------------------------------
# Optimized variant (§Perf): prefix-sum evaluation.
#
# The dense kernel above is O(B·K·S) — the faithful "assign every bucket"
# formulation. Observing that the histogram is FIXED across the candidate
# batch, precompute (in the surrounding jax graph, fused by XLA, O(S)):
#
#   pc[i] = Σ_{j<i} hist[j]              (item-count prefix)
#   pb[i] = Σ_{j<i} hist[j]·sizes[j]     (byte prefix)
#
# For an ASCENDING candidate row (c_1 ≤ … ≤ c_K — the optimizer always
# works with sorted configurations; the rust backend sorts before
# padding) over UNIFORM-width buckets (sizes[i] = (i+1)·w — what
# `SizeHistogram::bucketize` emits, w = 1 for every paper workload):
#
#   ub(c)  = clip(floor(c / w), 0, S)           # buckets covered by c
#   waste  = Σ_k c_k·(pc[ub_k] − pc[ub_{k−1}]) − (pb[ub_k] − pb[ub_{k−1}])
#          + SENTINEL·(pc[S] − pc[ub_K]) − (pb[S] − pb[ub_K])
#
# This is O(K) gathers per candidate — the same algebra as the rust
# prefix-sum evaluator, so results stay bit-identical (all quantities
# are integers < 2^53; integer f64 sums are associativity-exact).
# Measured on this image: 256-candidate batch 301 ms → sub-ms.
# ---------------------------------------------------------------------------


def _waste_prefix_kernel(pc_ref, pb_ref, w_ref, cfg_ref, out_ref):
    """One B_TILE row-block: prefix-sum waste for sorted candidates."""
    pc = pc_ref[...]  # [S+1]
    pb = pb_ref[...]  # [S+1]
    w = w_ref[0]
    cfg = cfg_ref[...]  # [B_TILE, K]
    s_buckets = pc.shape[0] - 1

    idx = jnp.clip((cfg / w).astype(jnp.int32), 0, s_buckets)  # [B, K]
    cum_c = jnp.take(pc, idx)  # items covered up to c_k
    cum_b = jnp.take(pb, idx)
    prev_c = jnp.concatenate([jnp.zeros_like(cum_c[:, :1]), cum_c[:, :-1]], axis=1)
    prev_b = jnp.concatenate([jnp.zeros_like(cum_b[:, :1]), cum_b[:, :-1]], axis=1)
    per_class = cfg * (cum_c - prev_c) - (cum_b - prev_b)
    covered = per_class.sum(axis=1)
    tail_c = pc[s_buckets] - cum_c[:, -1]
    tail_b = pb[s_buckets] - cum_b[:, -1]
    out_ref[...] = covered + SENTINEL * tail_c - tail_b


@functools.partial(jax.jit, static_argnames=("b_tile",))
def waste_eval_prefix(
    hist: jax.Array,
    sizes: jax.Array,
    configs: jax.Array,
    *,
    b_tile: int | None = None,
) -> jax.Array:
    """Fast batched waste for ASCENDING rows over uniform-width buckets.

    Same signature and (for sorted rows) bit-identical results as
    [`waste_eval`]; see the block comment above. `sizes` must satisfy
    `sizes[i] = (i+1)·sizes[0]` — callers (aot test vectors, the rust
    `bucketize`) guarantee this.
    """
    s_buckets = hist.shape[0]
    b_cands, k_classes = configs.shape
    if b_tile is None:
        b_tile = _largest_divisor_tile(b_cands, B_TILE)

    w = sizes[0]
    zero = jnp.zeros((1,), dtype=hist.dtype)
    # NOT jnp.cumsum: that lowers to reduce_window, which the target
    # xla_extension 0.5.1 CPU executes naively in O(S²) (~100 ms at
    # S=16384). Log-step doubling is O(S log S), 14 shifted adds, and
    # bit-exact (integer sums are associativity-exact below 2^53).
    def prefix_sum(x):
        n = x.shape[0]
        shift = 1
        while shift < n:
            x = x + jnp.pad(x[:-shift], (shift, 0))
            shift *= 2
        return x

    pc = jnp.concatenate([zero, prefix_sum(hist)])
    pb = jnp.concatenate([zero, prefix_sum(hist * sizes)])

    return pl.pallas_call(
        _waste_prefix_kernel,
        grid=(b_cands // b_tile,),
        in_specs=[
            pl.BlockSpec((s_buckets + 1,), lambda b: (0,)),  # pc
            pl.BlockSpec((s_buckets + 1,), lambda b: (0,)),  # pb
            pl.BlockSpec((1,), lambda b: (0,)),  # bucket width
            pl.BlockSpec((b_tile, k_classes), lambda b: (b, 0)),  # configs
        ],
        out_specs=pl.BlockSpec((b_tile,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((b_cands,), configs.dtype),
        interpret=True,
    )(pc, pb, w.reshape(1), configs)
