"""Correctness oracles for the L1 waste kernel.

Three references:

* ``waste_ref_jnp`` — pure jax.numpy, vectorized; the shape/dtype twin of
  the Pallas kernel. Used to validate the kernel under hypothesis sweeps.
* ``waste_ref_numpy`` — host-side numpy twin for quick checks.
* ``waste_exact`` — plain-python integer arithmetic; the ground truth
  both the kernel and the rust evaluator must match *bit-exactly*
  (every quantity is an integer < 2^53 held in f64).

Semantics are defined in waste.py: each histogram bucket is charged the
smallest covering chunk, uncovered buckets are charged SENTINEL.
"""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from .waste import SENTINEL


def waste_ref_jnp(hist, sizes, configs):
    """f64[S], f64[S], f64[B, K] -> f64[B], pure jnp (no pallas)."""
    # [B, K, S]: chunk candidates where they cover the bucket, else SENTINEL.
    covers = configs[:, :, None] >= sizes[None, None, :]
    cand = jnp.where(covers, configs[:, :, None], SENTINEL)
    chunk = jnp.min(cand, axis=1)  # [B, S]
    return jnp.sum((chunk - sizes[None, :]) * hist[None, :], axis=1)


def waste_ref_numpy(hist, sizes, configs):
    """Same as waste_ref_jnp but numpy, for host-side checks."""
    hist = np.asarray(hist, dtype=np.float64)
    sizes = np.asarray(sizes, dtype=np.float64)
    configs = np.asarray(configs, dtype=np.float64)
    covers = configs[:, :, None] >= sizes[None, None, :]
    cand = np.where(covers, configs[:, :, None], SENTINEL)
    chunk = cand.min(axis=1)
    return ((chunk - sizes[None, :]) * hist[None, :]).sum(axis=1)


def waste_exact(
    hist: Sequence[int], sizes: Sequence[int], config: Sequence[int]
) -> int:
    """Ground-truth waste for ONE configuration, arbitrary-precision ints."""
    sentinel = int(SENTINEL)
    total = 0
    for h, s in zip(hist, sizes):
        if h == 0:
            continue
        chunk = min((c for c in config if c >= s), default=sentinel)
        total += int(h) * (chunk - int(s))
    return total


def waste_exact_batch(hist, sizes, configs) -> list:
    """Ground truth for a batch of configurations."""
    return [waste_exact(hist, sizes, row) for row in configs]
